//! [`SolverService`] — the thread-safe serving façade, now an
//! **asynchronous job endpoint**.
//!
//! One service owns (a) a registry of matrices behind opaque
//! [`MatrixHandle`]s, (b) the LRU [`PlanCache`] behind an `RwLock` with a
//! per-[`PlanKey`] build gate (concurrent same-key requests trigger exactly
//! one plan build), and (c) a job queue drained by one dispatcher thread
//! (`api::queue`). [`submit`](SolverService::submit) enqueues one
//! right-hand side and returns a [`JobHandle`] immediately; the dispatcher
//! micro-batches compatible jobs onto one session, so concurrent
//! single-RHS traffic shares one plan checkout and one warmed-up pool
//! instead of paying per-request setup. The blocking
//! [`solve`](SolverService::solve) / [`solve_many`](SolverService::solve_many)
//! calls are thin submit + wait wrappers over the same queue, so existing
//! callers keep working — and transparently coalesce with each other.
//!
//! Dropping the service shuts the queue down gracefully: no new
//! submissions, everything already queued is flushed, then the dispatcher
//! thread is joined.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering as AtomicOrdering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::{QueueConfig, SolverConfig};
use crate::coordinator::driver::{SolveOptions, SolveReport};
use crate::coordinator::report::{micros, Table};
use crate::coordinator::session::{CacheStats, PlanCache, PlanKey, SolveOutput, SolveSession};
use crate::error::{HbmcError, Result};
use crate::obs::flight::PHASE_NAMES;
use crate::obs::metrics::{Counter, Gauge, Histogram, MetricsRegistry, MetricsSnapshot};
use crate::obs::prometheus::{self, write_counter, write_gauge};
use crate::obs::trace::{stage, TraceRecorder};
use crate::resil::{BreakerState, CircuitBreaker, FaultInjector};
use crate::solver::plan::SolverPlan;
use crate::sparse::csr::Csr;
use crate::tune::{tune_matrix, HardwareSignature, ProfileStore, TuneOptions, TunedProfile};

use super::job::{InflightGuard, JobCore, JobHandle};
use super::queue::{dispatcher_loop, BatchKey, JobQueue, QueuedJob};

/// Opaque ticket for a matrix registered with a [`SolverService`]. Cheap to
/// copy and share across threads. Ids are allocated from one process-wide
/// counter, so a handle presented to a service other than its issuer can
/// never alias a different matrix — it fails with
/// [`HbmcError::UnknownMatrix`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MatrixHandle(u64);

impl MatrixHandle {
    /// The raw registry id (diagnostics, log correlation).
    pub fn id(&self) -> u64 {
        self.0
    }
}

/// Process-wide handle allocator (see [`MatrixHandle`]). Relaxed suffices:
/// ids only need to be unique, which atomicity alone guarantees.
static NEXT_MATRIX_ID: AtomicU64 = AtomicU64::new(1);

/// A registry entry: the shared matrix plus its content fingerprint,
/// hashed once at registration (an O(nnz) scan) rather than per request.
#[derive(Clone)]
pub(crate) struct Registered {
    /// The handle id this entry was registered under (keys the per-handle
    /// circuit breaker from inside the dispatcher, where only the snapshot
    /// travels with the job).
    pub(crate) id: u64,
    pub(crate) matrix: Arc<Csr>,
    pub(crate) fingerprint: u64,
    /// Jobs currently in flight (submitted, not yet terminal) against this
    /// handle — the denominator of `max_inflight_per_handle`. Shared by
    /// every clone of the entry (queued jobs capture a clone), so the
    /// quota follows the handle, not the snapshot. Re-registering a matrix
    /// mints a fresh handle and with it a fresh quota.
    pub(crate) inflight: Arc<AtomicUsize>,
}

/// Per-request overrides layered on the service's default configuration.
///
/// `config` swaps the *structural* configuration (ordering, bs, w, storage
/// — a different [`PlanKey`], hence possibly a different cached plan);
/// `options` carries the per-solve knobs (rtol/max_iters overrides,
/// history, solution copy) that never invalidate a plan; `deadline` bounds
/// how long a submitted job may sit in the queue before it is failed with
/// [`HbmcError::DeadlineExceeded`] instead of dispatched.
#[derive(Debug, Clone, Default)]
pub struct SolveRequest {
    /// Structural config for this request; `None` = the service default.
    /// (The `queue` field of an override is ignored — dispatcher tuning is
    /// service-level.)
    pub config: Option<SolverConfig>,
    /// Per-solve options (tolerance/iteration overrides, history, …).
    pub options: SolveOptions,
    /// Turn a non-converged result into [`HbmcError::NotConverged`]
    /// instead of an `Ok` report with `converged == false`.
    pub require_convergence: bool,
    /// Maximum time the job may wait in the queue before dispatch. Checked
    /// when the dispatcher reaches the job: an expired job never runs; a
    /// job that started before expiry always finishes.
    pub deadline: Option<Duration>,
    /// Opt out of automatic tuned-profile application for this request
    /// (see [`SolverService::tune`]): solve under the service default even
    /// when a profile is installed for the matrix. Irrelevant when
    /// `config` is set — an explicit override always wins.
    pub skip_profile: bool,
}

impl SolveRequest {
    pub fn new() -> SolveRequest {
        SolveRequest::default()
    }

    /// Use this structural config (a different plan-cache key) instead of
    /// the service default.
    pub fn with_config(mut self, cfg: SolverConfig) -> SolveRequest {
        self.config = Some(cfg);
        self
    }

    /// Override the convergence tolerance for this request only.
    pub fn rtol(mut self, rtol: f64) -> SolveRequest {
        self.options.rtol = Some(rtol);
        self
    }

    /// Override the iteration cap for this request only.
    pub fn max_iters(mut self, max_iters: usize) -> SolveRequest {
        self.options.max_iters = Some(max_iters);
        self
    }

    /// Record the per-iteration residual history.
    pub fn record_history(mut self) -> SolveRequest {
        self.options.record_history = true;
        self
    }

    /// Copy the solution vector into the report.
    pub fn return_solution(mut self) -> SolveRequest {
        self.options.return_solution = true;
        self
    }

    /// Fail with [`HbmcError::NotConverged`] when the cap is reached.
    pub fn require_convergence(mut self) -> SolveRequest {
        self.require_convergence = true;
        self
    }

    /// Fail the job with [`HbmcError::DeadlineExceeded`] if it is still
    /// queued `budget` after submission (see the field docs).
    pub fn deadline(mut self, budget: Duration) -> SolveRequest {
        self.deadline = Some(budget);
        self
    }

    /// Solve under the service default even when a tuned profile is
    /// installed for the matrix (per-request opt-out of auto-application).
    pub fn no_profile(mut self) -> SolveRequest {
        self.skip_profile = true;
        self
    }
}

/// Point-in-time service counters: registry size, plan-cache counters,
/// build/coalescing behaviour under concurrency, and the job queue's
/// batching statistics.
#[derive(Debug, Clone, Copy)]
pub struct ServiceStats {
    /// Matrices currently registered.
    pub matrices: usize,
    /// Plan-cache snapshot (len/capacity/hits/misses/evictions).
    pub cache: CacheStats,
    /// Plans actually built by this service (== cache misses).
    pub builds: u64,
    /// Requests that waited on another thread's in-flight build instead of
    /// building themselves.
    pub coalesced_builds: u64,
    /// Solves completed through the service.
    pub solves: u64,
    /// Jobs currently waiting in the queue (not yet dispatched).
    pub queue_depth: usize,
    /// Micro-batches the dispatcher has run (each = one plan checkout +
    /// one session).
    pub batches: u64,
    /// Total right-hand sides dispatched across all batches.
    pub batched_rhs: u64,
    /// Right-hand sides that rode in a batch of width ≥ 2 — i.e. requests
    /// that shared a session with at least one other request.
    pub coalesced_rhs: u64,
    /// Total `Pool::run` dispatches across all solves completed through
    /// the job queue. With the fused single-dispatch loop this equals
    /// `solves`; the legacy loop pays ~3 per CG iteration. (Solves on
    /// queue-bypass `session()` handles are not counted.)
    pub dispatches: u64,
    /// Tuned profiles currently installed (via [`SolverService::tune`],
    /// [`install_profile`](SolverService::install_profile) or an attached
    /// store).
    pub profiles: usize,
    /// Requests that ran under an auto-applied tuned profile (no explicit
    /// config override, profile present, not opted out).
    pub profile_hits: u64,
    /// [`SolverService::tune`] runs completed on this service.
    pub tunes: u64,
    /// Submissions rejected synchronously by admission control with
    /// [`HbmcError::Overloaded`] — the queue-depth bound and the
    /// per-handle in-flight quota combined (the Prometheus export splits
    /// them by `reason`).
    pub overloaded: u64,
    /// Jobs shed at dispatch because their deadline had already expired
    /// (they failed typed with [`HbmcError::DeadlineExceeded`], never ran).
    pub shed: u64,
}

impl ServiceStats {
    /// Mean dispatched batch width (`batched_rhs / batches`); 0 before the
    /// first batch.
    pub fn mean_batch_width(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_rhs as f64 / self.batches as f64
        }
    }
}

// Lock helpers: the service never panics while holding a lock on the hot
// path, but a poisoned lock must not cascade — recover the guard.
fn wlock<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

fn rlock<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

pub(crate) fn mlock<T>(l: &Mutex<T>) -> MutexGuard<'_, T> {
    l.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Reject a right-hand side containing NaN/±Inf before it reaches the
/// queue or the plan cache: one non-finite entry poisons every inner
/// product of the CG iteration, so the solve can only end in a breakdown —
/// fail it synchronously and name the first offending index instead.
fn check_rhs_finite(rhs: &[f64]) -> Result<()> {
    if let Some(i) = rhs.iter().position(|v| !v.is_finite()) {
        return Err(HbmcError::invalid_config(format!(
            "rhs[{i}] is {}; right-hand sides must be finite",
            rhs[i]
        )));
    }
    Ok(())
}

/// Observability state owned by the service core: the metric registry,
/// the `Arc` handles the hot paths write through (no registry lookup per
/// observation), and the bounded lifecycle-trace ring.
///
/// Everything here *measures*; nothing here times the fused one-dispatch
/// solve region — solve/phase figures are taken from the `SolveReport` the
/// coordinator already produces, so PR 4's determinism and sync counts are
/// untouched by observability being on or off.
pub(crate) struct ServiceObs {
    registry: MetricsRegistry,
    /// Queue wait per dispatched job, µs (submission → claim).
    pub(crate) queue_wait_us: Arc<Histogram>,
    /// Started jobs per dispatched micro-batch.
    pub(crate) batch_width: Arc<Histogram>,
    /// Plan setup (ordering + factorization) time per build, µs.
    setup_us: Arc<Histogram>,
    /// Iteration-loop wall time per solve, µs.
    solve_us: Arc<Histogram>,
    /// CG iterations per solve.
    iterations: Arc<Histogram>,
    /// `Overloaded` rejections, split by which bound tripped.
    pub(crate) overloaded_depth: Arc<Counter>,
    pub(crate) overloaded_inflight: Arc<Counter>,
    /// Jobs shed at dispatch (deadline already expired).
    pub(crate) shed: Arc<Counter>,
    /// Recovery-ladder retries, split by what failed (`crate::resil`).
    pub(crate) retry_panic: Arc<Counter>,
    pub(crate) retry_breakdown_factorization: Arc<Counter>,
    pub(crate) retry_breakdown_iteration: Arc<Counter>,
    pub(crate) retry_not_converged: Arc<Counter>,
    /// Sessions whose pool was drained and rebuilt after a worker panic.
    pub(crate) pool_rebuilds: Arc<Counter>,
    /// Worst circuit-breaker state across handles (0=closed, 1=half-open,
    /// 2=open); stays 0 with no breakers configured. Deliberately *not* in
    /// the registry: `metrics_text` renders the whole `hbmc_breaker_state`
    /// family itself (worst-state sample plus one `{handle=…}` sample per
    /// breaker, computed at scrape time), and a registry copy would emit a
    /// duplicate `TYPE` block.
    pub(crate) breaker_state: Arc<Gauge>,
    /// Per-solve kernel-phase busy time from the opt-in flight recorder,
    /// µs. One labeled series per (ordering, phase); flattened row-major
    /// as `ordering_idx * PHASE_NAMES.len() + phase_idx` and registered
    /// contiguously so the exposition renders one family block.
    kernel_phase_us: Vec<Arc<Histogram>>,
    /// Barrier-wait imbalance (max/mean across threads) of the most
    /// recently profiled solve; 1.0 = perfectly balanced.
    barrier_imbalance: Arc<Gauge>,
    /// Cumulative per-phase time, µs, from report fields (see type docs).
    phase_setup: Arc<Counter>,
    phase_trisolve: Arc<Counter>,
    phase_spmv: Arc<Counter>,
    phase_blas1: Arc<Counter>,
    /// Lifecycle trace ring shared with sampled jobs.
    pub(crate) trace: Arc<TraceRecorder>,
    /// Every `trace_sample`-th submission is traced; 0 disables.
    trace_sample: usize,
    /// Submission counter driving the sampler.
    submitted: AtomicU64,
}

/// Events the trace ring holds before evicting the oldest (~8 full
/// 8-event job lifecycles per 64 jobs at `trace_sample = 1`).
const TRACE_CAPACITY: usize = 1024;

/// Label values of the `ordering` dimension of
/// `hbmc_kernel_phase_microseconds`, in registration order (must match
/// [`ordering_metric_label`]).
const ORDERING_LABELS: [&str; 5] = ["natural", "mc", "bmc", "hbmc", "level"];

/// Index into [`ORDERING_LABELS`] for a plan's `config_label` (which
/// always starts with the ordering's display form, e.g. `HBMC(bs=8,…)`).
fn ordering_metric_label(config_label: &str) -> Option<usize> {
    let ordering = config_label.split('(').next().unwrap_or("");
    ORDERING_LABELS.iter().position(|l| ordering.eq_ignore_ascii_case(l))
}

impl ServiceObs {
    fn new(queue: &QueueConfig) -> ServiceObs {
        let r = MetricsRegistry::new();
        let mut kernel_phase_us = Vec::with_capacity(ORDERING_LABELS.len() * PHASE_NAMES.len());
        for ordering in ORDERING_LABELS {
            for phase in PHASE_NAMES {
                kernel_phase_us.push(r.histogram_with(
                    "hbmc_kernel_phase_microseconds",
                    &format!("phase=\"{phase}\",ordering=\"{ordering}\""),
                    "Per-solve kernel-phase busy time from the in-region flight recorder \
                     (profiled solves only).",
                ));
            }
        }
        ServiceObs {
            overloaded_depth: r.counter_with(
                "hbmc_overloaded_total",
                "reason=\"queue_depth\"",
                "Submissions rejected by admission control.",
            ),
            overloaded_inflight: r.counter_with(
                "hbmc_overloaded_total",
                "reason=\"inflight\"",
                "Submissions rejected by admission control.",
            ),
            shed: r.counter(
                "hbmc_shed_total",
                "Jobs shed at dispatch because their deadline had expired.",
            ),
            retry_panic: r.counter_with(
                "hbmc_retries_total",
                "cause=\"panic\"",
                "Recovery-ladder retries, by failure cause.",
            ),
            retry_breakdown_factorization: r.counter_with(
                "hbmc_retries_total",
                "cause=\"breakdown_factorization\"",
                "Recovery-ladder retries, by failure cause.",
            ),
            retry_breakdown_iteration: r.counter_with(
                "hbmc_retries_total",
                "cause=\"breakdown_iteration\"",
                "Recovery-ladder retries, by failure cause.",
            ),
            retry_not_converged: r.counter_with(
                "hbmc_retries_total",
                "cause=\"not_converged\"",
                "Recovery-ladder retries, by failure cause.",
            ),
            pool_rebuilds: r.counter(
                "hbmc_pool_rebuilds_total",
                "Sessions whose pool was drained and rebuilt after a worker panic.",
            ),
            breaker_state: Arc::new(Gauge::new()),
            barrier_imbalance: r.gauge(
                "hbmc_barrier_wait_imbalance",
                "Barrier-wait imbalance (max/mean across threads) of the most recently \
                 profiled solve; 1 = perfectly balanced.",
            ),
            phase_setup: r.counter_with(
                "hbmc_phase_microseconds_total",
                "phase=\"setup\"",
                "Cumulative time per solver phase.",
            ),
            phase_trisolve: r.counter_with(
                "hbmc_phase_microseconds_total",
                "phase=\"trisolve\"",
                "Cumulative time per solver phase.",
            ),
            phase_spmv: r.counter_with(
                "hbmc_phase_microseconds_total",
                "phase=\"spmv\"",
                "Cumulative time per solver phase.",
            ),
            phase_blas1: r.counter_with(
                "hbmc_phase_microseconds_total",
                "phase=\"blas1\"",
                "Cumulative time per solver phase.",
            ),
            queue_wait_us: r.histogram(
                "hbmc_queue_wait_microseconds",
                "Queue wait per dispatched job (submission to claim).",
            ),
            batch_width: r.histogram(
                "hbmc_batch_width",
                "Started jobs per dispatched micro-batch.",
            ),
            setup_us: r.histogram(
                "hbmc_setup_microseconds",
                "Plan setup (ordering + IC(0) factorization) time per build.",
            ),
            solve_us: r.histogram(
                "hbmc_solve_microseconds",
                "Iteration-loop wall time per solve.",
            ),
            iterations: r.histogram("hbmc_solve_iterations", "CG iterations per solve."),
            kernel_phase_us,
            trace: Arc::new(TraceRecorder::new(TRACE_CAPACITY)),
            trace_sample: queue.trace_sample,
            submitted: AtomicU64::new(0),
            registry: r,
        }
    }

    /// The trace ring for this submission, if the sampler picks it
    /// (every `trace_sample`-th job; the first always qualifies).
    pub(crate) fn trace_for_next_job(&self) -> Option<Arc<TraceRecorder>> {
        if self.trace_sample == 0 {
            return None;
        }
        let index = self.submitted.fetch_add(1, AtomicOrdering::Relaxed);
        (index % self.trace_sample as u64 == 0).then(|| Arc::clone(&self.trace))
    }

    /// Fold one completed solve's report into the histograms and phase
    /// counters (dispatcher thread, after the solve — never inside it).
    pub(crate) fn record_solve(&self, report: &SolveReport) {
        self.solve_us.observe((report.solve_seconds * 1e6) as u64);
        self.iterations.observe(report.iterations as u64);
        for (name, seconds) in &report.kernel_seconds {
            let us = (seconds * 1e6) as u64;
            match *name {
                "trisolve" => self.phase_trisolve.add(us),
                "spmv" => self.phase_spmv.add(us),
                "blas1" => self.phase_blas1.add(us),
                _ => {}
            }
        }
        // Profiled solves additionally carry the flight recorder's exact
        // per-phase totals: one observation per (ordering, phase) series.
        if let Some(profile) = &report.profile {
            if let Some(o) = ordering_metric_label(&report.plan.config_label) {
                for (p, seconds) in profile.phase_totals().iter().enumerate() {
                    let idx = o * PHASE_NAMES.len() + p;
                    self.kernel_phase_us[idx].observe((seconds * 1e6) as u64);
                }
            }
            self.barrier_imbalance.set(profile.barrier_wait_imbalance());
        }
    }

    /// Count one recovery-ladder retry under its cause label (the values
    /// of [`RetryAttempt::cause`](crate::coordinator::driver::RetryAttempt)).
    pub(crate) fn record_retry(&self, cause: &str) {
        match cause {
            "panic" => self.retry_panic.inc(),
            "breakdown_factorization" => self.retry_breakdown_factorization.inc(),
            "breakdown_iteration" => self.retry_breakdown_iteration.inc(),
            "not_converged" => self.retry_not_converged.inc(),
            _ => {}
        }
    }

    /// Fold one plan build's setup time in (build thread, after the build).
    pub(crate) fn record_setup(&self, setup_seconds: f64) {
        let us = (setup_seconds * 1e6) as u64;
        self.setup_us.observe(us);
        self.phase_setup.add(us);
    }

    pub(crate) fn snapshot(&self) -> MetricsSnapshot {
        self.registry.snapshot()
    }
}

/// The service state shared between request threads and the dispatcher
/// thread: registry, plan cache + build gates, and the statistics counters.
pub(crate) struct ServiceCore {
    default_cfg: SolverConfig,
    /// The host this service runs on — the hardware half of every profile
    /// key (detected once at construction).
    hardware: HardwareSignature,
    matrices: RwLock<HashMap<u64, Registered>>,
    cache: RwLock<PlanCache>,
    /// Installed tuned profiles by matrix fingerprint. Only profiles
    /// matching `hardware` are ever admitted, so the fingerprint alone
    /// keys this map.
    profiles: RwLock<HashMap<u64, TunedProfile>>,
    /// Store file `tune` persists into (set by `attach_profile_store`).
    profile_store: Mutex<Option<PathBuf>>,
    /// Per-key build gates: the map lock is held only to look up/insert a
    /// gate; the gate itself is held for the duration of one plan build.
    building: Mutex<HashMap<PlanKey, Arc<Mutex<()>>>>,
    // Monotonic statistics counters. `Relaxed` is deliberate and
    // sufficient: each is independently monotonic and read only for
    // reporting — nothing establishes happens-before through them (the
    // data they describe synchronizes via the registry/cache locks and the
    // job-state mutexes). They are not synchronization points; `SeqCst`
    // would only add fences on the hot path.
    builds: AtomicU64,
    coalesced: AtomicU64,
    solves: AtomicU64,
    dispatches: AtomicU64,
    profile_hits: AtomicU64,
    tunes: AtomicU64,
    /// The chaos-engineering fault injector, armed from
    /// `SolverConfig::fault` at construction; `None` (the production
    /// default) keeps every hook on the fault path a null check.
    injector: Option<Arc<FaultInjector>>,
    /// Consecutive-failure threshold for the per-handle circuit breakers;
    /// `None` disables the breakers entirely.
    breaker_threshold: Option<u32>,
    /// Per-handle circuit breakers, created lazily at first submission
    /// (only when `breaker_threshold` is set).
    breakers: RwLock<HashMap<u64, Arc<CircuitBreaker>>>,
    /// Metrics, histograms, and the lifecycle-trace ring (see
    /// [`ServiceObs`]); written by request threads and the dispatcher.
    pub(crate) obs: ServiceObs,
}

impl ServiceCore {
    pub(crate) fn registered(&self, handle: MatrixHandle) -> Result<Registered> {
        rlock(&self.matrices)
            .get(&handle.0)
            .cloned()
            .ok_or_else(|| HbmcError::UnknownMatrix(format!("handle #{}", handle.0)))
    }

    /// The tuned config for a matrix, if a profile is installed: the
    /// profile's structural choice overlaid on the service default (the
    /// default's convergence contract is preserved — see
    /// `TunedProfile::apply_to`).
    fn tuned_config(&self, fingerprint: u64) -> Option<SolverConfig> {
        rlock(&self.profiles).get(&fingerprint).map(|p| p.apply_to(&self.default_cfg))
    }

    /// Get-or-build with single-build coalescing (see `plan` on the
    /// service). Called by request threads and by the dispatcher.
    pub(crate) fn plan_for(&self, reg: &Registered, cfg: &SolverConfig) -> Result<Arc<SolverPlan>> {
        let key = PlanKey::from_fingerprint(reg.fingerprint, cfg);
        // Fast path: cached (write lock — `get` touches the LRU clock).
        if let Some(plan) = wlock(&self.cache).get(&key) {
            return Ok(plan);
        }
        // Slow path: take this key's build gate so one thread builds while
        // the rest wait here, not in a duplicate factorization.
        let gate = mlock(&self.building).entry(key.clone()).or_default().clone();
        let permit = mlock(&gate);
        // Re-check under the gate: whoever held it before us has inserted.
        if let Some(plan) = wlock(&self.cache).get(&key) {
            self.coalesced.fetch_add(1, AtomicOrdering::Relaxed);
            drop(permit);
            self.release_gate(&key, &gate);
            return Ok(plan);
        }
        let result = SolverPlan::build_with(&reg.matrix, cfg, self.injector.as_deref()).map(|plan| {
            let plan = Arc::new(plan);
            self.builds.fetch_add(1, AtomicOrdering::Relaxed);
            self.obs.record_setup(plan.setup.setup_seconds());
            wlock(&self.cache).insert(key.clone(), plan.clone());
            plan
        });
        drop(permit);
        self.release_gate(&key, &gate);
        result
    }

    /// Retire a build gate once no other thread is waiting on it. Removing
    /// only when we hold the map's sole outside reference keeps the gate
    /// stable while contended — every concurrent requester for a key always
    /// serializes on the *same* mutex, so a rebuilt (failed or evicted) key
    /// can never be built twice at once — while still letting idle entries
    /// be reclaimed instead of accumulating per distinct key.
    fn release_gate(&self, key: &PlanKey, gate: &Arc<Mutex<()>>) {
        let mut map = mlock(&self.building);
        // Strong refs on the entry: the map's + ours (`gate`) + one per
        // thread that has fetched it and not yet released. <= 2 means
        // nobody else can be waiting; a later requester must go through
        // the map lock we hold, so the count cannot grow under us.
        let retire = map
            .get(key)
            .is_some_and(|current| Arc::ptr_eq(current, gate) && Arc::strong_count(current) <= 2);
        if retire {
            map.remove(key);
        }
    }

    /// Count one completed solve (called by the dispatcher per rhs).
    pub(crate) fn note_solve(&self) {
        self.solves.fetch_add(1, AtomicOrdering::Relaxed);
    }

    /// Drop a plan from the cache outright (poisoned-batch recovery: a
    /// solver panic implicates the plan a worker was reading when it
    /// died). The next request for this `PlanKey` rebuilds from the
    /// matrix instead of re-checking out a suspect plan; the per-key
    /// build gate still guarantees the rebuild happens exactly once under
    /// concurrency.
    pub(crate) fn evict_plan(&self, key: &PlanKey) -> bool {
        wlock(&self.cache).remove(key).is_some()
    }

    /// Accumulate a completed solve's pool-dispatch count.
    pub(crate) fn note_dispatches(&self, n: u64) {
        self.dispatches.fetch_add(n, AtomicOrdering::Relaxed);
    }

    /// The service-wide fault injector, if one is armed (chaos runs only).
    pub(crate) fn injector(&self) -> Option<&Arc<FaultInjector>> {
        self.injector.as_ref()
    }

    /// The circuit breaker for a handle, created on first use. `None`
    /// when breakers are disabled (`QueueConfig::breaker_threshold`).
    pub(crate) fn breaker_for(&self, handle_id: u64) -> Option<Arc<CircuitBreaker>> {
        let threshold = self.breaker_threshold?;
        if let Some(b) = rlock(&self.breakers).get(&handle_id) {
            return Some(Arc::clone(b));
        }
        Some(Arc::clone(
            wlock(&self.breakers)
                .entry(handle_id)
                .or_insert_with(|| Arc::new(CircuitBreaker::new(threshold))),
        ))
    }

    /// Fold one terminal job outcome into the handle's breaker and refresh
    /// the worst-state gauge. Called by the dispatcher; callers exclude
    /// outcomes that say nothing about the matrix (cancelled, deadline,
    /// overloaded).
    pub(crate) fn record_outcome(&self, handle_id: u64, ok: bool) {
        if let Some(b) = self.breaker_for(handle_id) {
            if ok {
                b.record_success();
            } else {
                b.record_failure();
            }
            self.refresh_breaker_gauge();
        }
    }

    /// Recompute the `hbmc_breaker_state` gauge as the worst state across
    /// all breakers (0 when none exist).
    fn refresh_breaker_gauge(&self) {
        let worst = rlock(&self.breakers)
            .values()
            .map(|b| b.state().gauge_value())
            .max()
            .unwrap_or(0);
        self.obs.breaker_state.set(worst as f64);
    }

    /// Per-handle breaker states for the labeled `hbmc_breaker_state`
    /// samples, sorted by handle id so scrape output is stable.
    pub(crate) fn breaker_states(&self) -> Vec<(u64, u64)> {
        let mut states: Vec<(u64, u64)> = rlock(&self.breakers)
            .iter()
            .map(|(id, b)| (*id, b.state().gauge_value()))
            .collect();
        states.sort_unstable_by_key(|&(id, _)| id);
        states
    }

    /// Service health for `/healthz`: `(healthy, body)`.
    ///
    /// * `unhealthy` (503) — breakers exist and every one of them is open:
    ///   the service is rejecting all solve traffic it has seen.
    /// * `degraded` (200) — some breaker is open/half-open, or jobs have
    ///   been shed at dispatch; partial service.
    /// * `ok` (200) otherwise.
    pub(crate) fn health(&self) -> (bool, String) {
        let states: Vec<BreakerState> =
            rlock(&self.breakers).values().map(|b| b.state()).collect();
        let open = states.iter().filter(|s| **s == BreakerState::Open).count();
        let half = states.iter().filter(|s| **s == BreakerState::HalfOpen).count();
        if !states.is_empty() && open == states.len() {
            return (false, format!("unhealthy: all {open} circuit breaker(s) open\n"));
        }
        let shed = self.obs.shed.get();
        if open > 0 || half > 0 {
            return (
                true,
                format!("degraded: {open} breaker(s) open, {half} half-open\n"),
            );
        }
        if shed > 0 {
            return (true, format!("degraded: {shed} job(s) shed at dispatch\n"));
        }
        (true, "ok\n".to_string())
    }
}

/// Thread-safe solve endpoint; see module docs. `Send + Sync` — share one
/// instance behind an `Arc` across all request threads.
pub struct SolverService {
    core: Arc<ServiceCore>,
    queue: Arc<JobQueue>,
    dispatcher: Option<JoinHandle<()>>,
}

/// Default plan-cache capacity (`SolverService::new`).
pub const DEFAULT_PLAN_CAPACITY: usize = 8;

impl SolverService {
    /// Service with the default configuration and plan-cache capacity.
    pub fn new() -> SolverService {
        SolverService::with_capacity(SolverConfig::default(), DEFAULT_PLAN_CAPACITY)
            .expect("default service must construct")
    }

    /// Service whose `solve(handle, b)` uses `default_cfg`; fails fast on
    /// an invalid config rather than at first request.
    pub fn with_config(default_cfg: SolverConfig) -> Result<SolverService> {
        SolverService::with_capacity(default_cfg, DEFAULT_PLAN_CAPACITY)
    }

    /// Full constructor: default config + plan-cache capacity (≥ 1). Also
    /// spawns the dispatcher thread, tuned by `default_cfg.queue`.
    pub fn with_capacity(default_cfg: SolverConfig, capacity: usize) -> Result<SolverService> {
        default_cfg.validate()?;
        if capacity == 0 {
            return Err(HbmcError::invalid_config("plan cache capacity must be >= 1"));
        }
        let queue_cfg = default_cfg.queue;
        let injector = default_cfg.fault.map(|spec| Arc::new(FaultInjector::new(spec)));
        let breaker_threshold = queue_cfg.breaker_threshold;
        let core = Arc::new(ServiceCore {
            default_cfg,
            hardware: HardwareSignature::detect(),
            matrices: RwLock::new(HashMap::new()),
            cache: RwLock::new(PlanCache::new(capacity)),
            profiles: RwLock::new(HashMap::new()),
            profile_store: Mutex::new(None),
            building: Mutex::new(HashMap::new()),
            builds: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            solves: AtomicU64::new(0),
            dispatches: AtomicU64::new(0),
            profile_hits: AtomicU64::new(0),
            tunes: AtomicU64::new(0),
            injector,
            breaker_threshold,
            breakers: RwLock::new(HashMap::new()),
            obs: ServiceObs::new(&queue_cfg),
        });
        let queue = Arc::new(JobQueue::new(queue_cfg));
        let dispatcher = {
            let (queue, core) = (Arc::clone(&queue), Arc::clone(&core));
            std::thread::Builder::new()
                .name("hbmc-dispatcher".into())
                .spawn(move || dispatcher_loop(queue, core))
                .map_err(|e| HbmcError::io("spawning the service dispatcher thread", e))?
        };
        Ok(SolverService { core, queue, dispatcher: Some(dispatcher) })
    }

    /// The configuration used when a request carries no override.
    pub fn default_config(&self) -> &SolverConfig {
        &self.core.default_cfg
    }

    /// Register a matrix; the returned handle addresses it in every later
    /// call. Registration never builds a plan — that happens lazily (and
    /// exactly once per distinct config) at first solve.
    pub fn register_matrix(&self, a: Csr) -> MatrixHandle {
        self.register_matrix_arc(Arc::new(a))
    }

    /// Zero-copy registration for callers that already share the matrix.
    /// The matrix is fingerprinted here, once, so later plan-cache lookups
    /// never rescan it.
    pub fn register_matrix_arc(&self, a: Arc<Csr>) -> MatrixHandle {
        let id = NEXT_MATRIX_ID.fetch_add(1, AtomicOrdering::Relaxed);
        let entry = Registered {
            id,
            fingerprint: a.fingerprint(),
            matrix: a,
            inflight: Arc::new(AtomicUsize::new(0)),
        };
        wlock(&self.core.matrices).insert(id, entry);
        MatrixHandle(id)
    }

    /// Drop a matrix from the registry. Cached plans for it age out of the
    /// LRU naturally; queued jobs captured their registry entry at submit
    /// time and are unaffected, as are in-flight solves holding the plan.
    pub fn unregister_matrix(&self, handle: MatrixHandle) -> Result<()> {
        match wlock(&self.core.matrices).remove(&handle.0) {
            Some(_) => Ok(()),
            None => Err(HbmcError::UnknownMatrix(format!("handle #{}", handle.0))),
        }
    }

    /// The registered matrix behind `handle`.
    pub fn matrix(&self, handle: MatrixHandle) -> Result<Arc<Csr>> {
        Ok(self.core.registered(handle)?.matrix)
    }

    /// Get-or-build the plan for `(handle, cfg)` with single-build
    /// coalescing (concurrent same-key requests produce exactly one
    /// `SolverPlan::build`).
    pub fn plan(&self, handle: MatrixHandle, cfg: &SolverConfig) -> Result<Arc<SolverPlan>> {
        cfg.validate()?;
        let reg = self.core.registered(handle)?;
        self.core.plan_for(&reg, cfg)
    }

    /// Open a [`SolveSession`] on the (cached or freshly built) plan for
    /// `(handle, cfg)`, with the request's pool width and tolerances — the
    /// power-user path that bypasses the job queue for callers that want
    /// to hold one session across a burst of solves themselves.
    pub fn session(&self, handle: MatrixHandle, cfg: &SolverConfig) -> Result<SolveSession> {
        let plan = self.plan(handle, cfg)?;
        Ok(SolveSession::for_request(plan, cfg))
    }

    /// Enqueue one right-hand side and return immediately with a
    /// [`JobHandle`] (poll / wait / cancel; see `api::job`).
    ///
    /// Validation (handle, config, rhs dimension) happens here, so a
    /// malformed request fails synchronously with a typed error and never
    /// occupies the queue. The dispatcher micro-batches this job with any
    /// other queued jobs that share its plan and session parameters —
    /// concurrent submitters against one matrix share one session instead
    /// of spinning up N.
    pub fn submit(
        &self,
        handle: MatrixHandle,
        rhs: &[f64],
        req: &SolveRequest,
    ) -> Result<JobHandle> {
        let reg = self.core.registered(handle)?;
        let (cfg, from_profile) = self.effective_config(&reg, req);
        cfg.validate()?;
        let n = reg.matrix.n();
        if rhs.len() != n {
            return Err(HbmcError::DimensionMismatch { expected: n, got: rhs.len() });
        }
        check_rhs_finite(rhs)?;
        if from_profile {
            self.core.profile_hits.fetch_add(1, AtomicOrdering::Relaxed);
        }
        self.enqueue(&reg, &cfg, rhs, req)
    }

    /// The configuration a request solves under: explicit override >
    /// auto-applied tuned profile (unless the request opted out) >
    /// service default. The boolean reports a profile application
    /// (`ServiceStats::profile_hits`). `SolverConfig` is a small all-`Copy`
    /// struct, so the clone is cheaper than the registry lookup before it.
    fn effective_config(&self, reg: &Registered, req: &SolveRequest) -> (SolverConfig, bool) {
        if let Some(cfg) = &req.config {
            return (cfg.clone(), false);
        }
        if !req.skip_profile {
            if let Some(cfg) = self.core.tuned_config(reg.fingerprint) {
                return (cfg, true);
            }
        }
        (self.core.default_cfg.clone(), false)
    }

    /// Admission control + enqueue for inputs already validated by the
    /// caller (`submit` per request; `solve_many_with` once for a whole
    /// batch). Every rejection here is synchronous and typed — nothing is
    /// enqueued on the error paths:
    ///
    /// 1. a zero deadline can never be met, so it fails
    ///    [`HbmcError::DeadlineExceeded`] now instead of being discovered
    ///    expired at dispatch time;
    /// 2. with `max_inflight_per_handle` set, a full per-handle quota
    ///    fails [`HbmcError::Overloaded`] (the claimed slot travels with
    ///    the job and frees at its terminal transition);
    /// 3. with `max_queue_depth` set, a full queue fails
    ///    [`HbmcError::Overloaded`] from the push itself.
    fn enqueue(
        &self,
        reg: &Registered,
        cfg: &SolverConfig,
        rhs: &[f64],
        req: &SolveRequest,
    ) -> Result<JobHandle> {
        if let Some(budget) = req.deadline {
            if budget.is_zero() {
                return Err(HbmcError::DeadlineExceeded { budget });
            }
        }
        // Per-handle circuit breaker: a handle whose recent solves keep
        // failing is rejected at the door until a half-open probe succeeds.
        if let Some(breaker) = self.core.breaker_for(reg.id) {
            if let Err(failures) = breaker.admit() {
                return Err(HbmcError::CircuitOpen { handle: reg.id, failures });
            }
        }
        let inflight = match self.core.default_cfg.queue.max_inflight_per_handle {
            Some(limit) => match InflightGuard::acquire(&reg.inflight, limit) {
                Ok(guard) => Some(guard),
                Err(depth) => {
                    self.core.obs.overloaded_inflight.inc();
                    return Err(HbmcError::Overloaded { depth, limit });
                }
            },
            None => None,
        };
        let trace = self.core.obs.trace_for_next_job();
        let key = BatchKey::new(PlanKey::from_fingerprint(reg.fingerprint, cfg), cfg);
        let core = JobCore::new(req.deadline, inflight, trace);
        core.note(stage::SUBMITTED);
        let pushed = self.queue.push(QueuedJob {
            core: Arc::clone(&core),
            key,
            rhs: rhs.to_vec(),
            cfg: cfg.clone(),
            options: req.options.clone(),
            require_convergence: req.require_convergence,
            reg: reg.clone(),
        });
        if let Err(e) = pushed {
            // The job never entered the queue; dropping its core releases
            // the in-flight slot (InflightGuard's Drop backstop).
            self.core.obs.overloaded_depth.inc();
            return Err(e);
        }
        core.note(stage::ENQUEUED);
        Ok(JobHandle::new(core))
    }

    /// Solve `A x = b` under the service's default configuration.
    ///
    /// A thin [`submit`](SolverService::submit) + wait wrapper: the call
    /// blocks, but the work rides the job queue, so simultaneous blocking
    /// callers against the same matrix still coalesce into shared batches.
    pub fn solve(&self, handle: MatrixHandle, b: &[f64]) -> Result<SolveOutput> {
        self.solve_with(handle, b, &SolveRequest::default())
    }

    /// Solve with per-request overrides (submit + wait; see
    /// [`solve`](SolverService::solve)).
    pub fn solve_with(
        &self,
        handle: MatrixHandle,
        b: &[f64],
        req: &SolveRequest,
    ) -> Result<SolveOutput> {
        self.submit(handle, b, req)?.wait()
    }

    /// Batched serving: all right-hand sides are submitted up front and
    /// dispatched on shared sessions. Results are index-aligned with
    /// `rhss`. An empty slice returns `Ok(vec![])` without touching the
    /// queue, the plan cache, or a session.
    pub fn solve_many<B: AsRef<[f64]>>(
        &self,
        handle: MatrixHandle,
        rhss: &[B],
    ) -> Result<Vec<SolveOutput>> {
        self.solve_many_with(handle, rhss, &SolveRequest::default())
    }

    /// Batched serving with per-request overrides (applied to every rhs).
    ///
    /// Dimension checks run up front, so a malformed batch is rejected
    /// before any job is enqueued. The batch result is all-or-nothing:
    /// with [`require_convergence`](SolveRequest::require_convergence),
    /// the first rhs that stalls fails the call, completed outputs are
    /// discarded, and the not-yet-dispatched remainder is cancelled
    /// (already-running rhss finish, unobserved) — solve rhss
    /// individually when partial results matter.
    pub fn solve_many_with<B: AsRef<[f64]>>(
        &self,
        handle: MatrixHandle,
        rhss: &[B],
        req: &SolveRequest,
    ) -> Result<Vec<SolveOutput>> {
        if rhss.is_empty() {
            return Ok(Vec::new());
        }
        let reg = self.core.registered(handle)?;
        let (cfg, from_profile) = self.effective_config(&reg, req);
        cfg.validate()?;
        let n = reg.matrix.n();
        // Reject every malformed rhs up front — a batch must not enqueue
        // (let alone run) halfway before tripping on rhs k.
        for b in rhss {
            let got = b.as_ref().len();
            if got != n {
                return Err(HbmcError::DimensionMismatch { expected: n, got });
            }
            check_rhs_finite(b.as_ref())?;
        }
        // Everything is validated; enqueue without re-checking per rhs.
        if from_profile {
            self.core.profile_hits.fetch_add(rhss.len() as u64, AtomicOrdering::Relaxed);
        }
        let mut handles: Vec<JobHandle> = Vec::with_capacity(rhss.len());
        for b in rhss {
            match self.enqueue(&reg, &cfg, b.as_ref(), req) {
                Ok(handle) => handles.push(handle),
                Err(e) => {
                    // Admission failed mid-batch. The batch result is
                    // all-or-nothing, so cancel what was already enqueued
                    // (running jobs finish, unobserved) and surface the
                    // admission error to the caller.
                    for handle in handles {
                        handle.cancel();
                    }
                    return Err(e);
                }
            }
        }
        let mut outs = Vec::with_capacity(handles.len());
        let mut jobs = handles.into_iter();
        while let Some(job) = jobs.next() {
            match job.wait() {
                Ok(out) => outs.push(out),
                Err(e) => {
                    // The batch result is discarded anyway — shed the
                    // not-yet-dispatched remainder instead of letting the
                    // dispatcher solve rhss nobody can observe. (Running
                    // jobs still finish; cancel is queued-only.)
                    for job in jobs {
                        job.cancel();
                    }
                    return Err(e);
                }
            }
        }
        Ok(outs)
    }

    /// The hardware signature this service detected at construction — the
    /// machine half of every profile key it will accept.
    pub fn hardware(&self) -> HardwareSignature {
        self.core.hardware
    }

    /// Search the valid configuration space for the registered matrix on
    /// this machine (see [`crate::tune`]), install the winning
    /// [`TunedProfile`] so subsequent default-config requests auto-apply
    /// it, and persist it to the attached store (if any;
    /// [`attach_profile_store`](SolverService::attach_profile_store)).
    ///
    /// The search solves against the deterministic representative
    /// right-hand side `A·1` — tuning measures kernel shape, which is
    /// rhs-independent. The incumbent (the service default config) always
    /// competes in the final round, so the returned profile's score is
    /// never worse than the default's on the same measurements.
    ///
    /// Runs synchronously on the caller's thread (it is a measurement, not
    /// a job — riding the queue would let production traffic perturb the
    /// timings and vice versa). Expect seconds of wall time for real
    /// matrices; tune at deploy/registration time, not per request.
    pub fn tune(&self, handle: MatrixHandle, opts: &TuneOptions) -> Result<TunedProfile> {
        let reg = self.core.registered(handle)?;
        let n = reg.matrix.n();
        let ones = vec![1.0; n];
        let mut b = vec![0.0; n];
        reg.matrix.mul_vec(&ones, &mut b);
        let outcome = tune_matrix(&reg.matrix, &b, &self.core.default_cfg, opts)?;
        let profile = outcome.profile;
        // Every fallible step runs before any state change, so an Err
        // return means "nothing happened" — no half-applied tune where the
        // in-memory profile is live but the store write failed (or vice
        // versa).
        if profile.hardware != self.core.hardware {
            // tune_matrix detects the hardware at measurement time; if it
            // no longer matches the signature this service was built under
            // (e.g. a cgroup CPU-quota change moved available_parallelism),
            // the profile is keyed to a machine this service will never
            // match — installing nothing and returning Ok would make
            // tuning look active while profile_hits stays 0 forever.
            return Err(HbmcError::Internal(format!(
                "hardware signature changed during tuning ({} -> {}); profile not installed",
                self.core.hardware, profile.hardware
            )));
        }
        profile.apply_to(&self.core.default_cfg).validate()?;
        // The mutex is held across the whole open → put → save
        // read-modify-write: two concurrent tune() calls (different
        // matrices, same store) must not interleave and lose each other's
        // profile on disk. Tuning is rare and already seconds-long, so
        // serializing the file update is free.
        let store_guard = mlock(&self.core.profile_store);
        if let Some(path) = store_guard.as_ref() {
            let mut store = ProfileStore::open(path)?;
            store.put(profile.clone());
            store.save()?;
        }
        drop(store_guard);
        wlock(&self.core.profiles).insert(profile.fingerprint, profile.clone());
        self.core.tunes.fetch_add(1, AtomicOrdering::Relaxed);
        Ok(profile)
    }

    /// Install a tuned profile for auto-application. Returns `Ok(false)`
    /// (not installed) when the profile was tuned on different hardware —
    /// the paper's cross-machine result is exactly that such a transplant
    /// mis-tunes — and [`HbmcError::InvalidConfig`] when the profile's
    /// structural choice does not validate against the service default.
    pub fn install_profile(&self, profile: TunedProfile) -> Result<bool> {
        if profile.hardware != self.core.hardware {
            return Ok(false);
        }
        profile.apply_to(&self.core.default_cfg).validate()?;
        wlock(&self.core.profiles).insert(profile.fingerprint, profile);
        Ok(true)
    }

    /// Bind a [`ProfileStore`] file to this service: load it now
    /// (installing every profile that matches this machine and validates;
    /// others are skipped) and persist future [`tune`](SolverService::tune)
    /// results into it. Returns the number of profiles installed. A
    /// missing file is an empty store; a corrupt one is
    /// [`HbmcError::Parse`].
    pub fn attach_profile_store(&self, path: impl AsRef<Path>) -> Result<usize> {
        let path = path.as_ref();
        let store = ProfileStore::open(path)?;
        *mlock(&self.core.profile_store) = Some(path.to_path_buf());
        let mut installed = 0;
        for profile in store.iter() {
            if self.install_profile(profile.clone()).unwrap_or(false) {
                installed += 1;
            }
        }
        Ok(installed)
    }

    /// The installed profile for a registered matrix, if any.
    pub fn profile(&self, handle: MatrixHandle) -> Result<Option<TunedProfile>> {
        let reg = self.core.registered(handle)?;
        Ok(rlock(&self.core.profiles).get(&reg.fingerprint).cloned())
    }

    /// Counters: registry size, cache hits/misses/evictions, coalesced
    /// builds, solves served, and the queue's batching statistics.
    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            matrices: rlock(&self.core.matrices).len(),
            cache: rlock(&self.core.cache).stats(),
            builds: self.core.builds.load(AtomicOrdering::Relaxed),
            coalesced_builds: self.core.coalesced.load(AtomicOrdering::Relaxed),
            solves: self.core.solves.load(AtomicOrdering::Relaxed),
            queue_depth: self.queue.depth(),
            batches: self.queue.batches(),
            batched_rhs: self.queue.batched_rhs(),
            coalesced_rhs: self.queue.coalesced_rhs(),
            dispatches: self.core.dispatches.load(AtomicOrdering::Relaxed),
            profiles: rlock(&self.core.profiles).len(),
            profile_hits: self.core.profile_hits.load(AtomicOrdering::Relaxed),
            tunes: self.core.tunes.load(AtomicOrdering::Relaxed),
            overloaded: self.core.obs.overloaded_depth.get()
                + self.core.obs.overloaded_inflight.get(),
            shed: self.core.obs.shed.get(),
        }
    }

    /// Every service metric in Prometheus text exposition format (0.0.4):
    /// the [`ServiceStats`] gauges and counters as `hbmc_*` families, plus
    /// the admission counters and the queue-wait / batch-width / setup /
    /// solve / iteration histograms. This is what
    /// [`MetricsServer`](crate::obs::MetricsServer) serves on `/metrics`
    /// (`hbmc serve --metrics-addr`); it can also be scraped off any
    /// in-process service directly. Families are documented in
    /// ARCHITECTURE.md ("Observability & admission control").
    pub fn metrics_text(&self) -> String {
        let s = self.stats();
        let mut out = String::new();
        write_gauge(&mut out, "hbmc_matrices", "Matrices currently registered.", s.matrices as f64);
        write_gauge(
            &mut out,
            "hbmc_queue_depth",
            "Jobs queued or staged into an open batch window (live).",
            s.queue_depth as f64,
        );
        write_gauge(&mut out, "hbmc_plan_cache_entries", "Plans currently cached.", s.cache.len as f64);
        write_gauge(&mut out, "hbmc_plan_cache_capacity", "Plan cache capacity.", s.cache.capacity as f64);
        write_gauge(
            &mut out,
            "hbmc_profiles_installed",
            "Tuned profiles currently installed.",
            s.profiles as f64,
        );
        write_counter(&mut out, "hbmc_plan_cache_hits_total", "Plan cache hits.", s.cache.hits);
        write_counter(&mut out, "hbmc_plan_cache_misses_total", "Plan cache misses.", s.cache.misses);
        write_counter(
            &mut out,
            "hbmc_plan_cache_evictions_total",
            "Plans evicted from the cache.",
            s.cache.evictions,
        );
        write_counter(&mut out, "hbmc_plan_builds_total", "Plans built by this service.", s.builds);
        write_counter(
            &mut out,
            "hbmc_coalesced_builds_total",
            "Requests that waited on another thread's in-flight plan build.",
            s.coalesced_builds,
        );
        write_counter(&mut out, "hbmc_solves_total", "Solves completed through the service.", s.solves);
        write_counter(&mut out, "hbmc_batches_total", "Micro-batches dispatched.", s.batches);
        write_counter(
            &mut out,
            "hbmc_batched_rhs_total",
            "Right-hand sides dispatched across all batches.",
            s.batched_rhs,
        );
        write_counter(
            &mut out,
            "hbmc_coalesced_rhs_total",
            "Right-hand sides that rode a batch of width >= 2.",
            s.coalesced_rhs,
        );
        write_counter(
            &mut out,
            "hbmc_dispatches_total",
            "Pool::run dispatches across all queue solves.",
            s.dispatches,
        );
        write_counter(
            &mut out,
            "hbmc_profile_hits_total",
            "Requests served under an auto-applied tuned profile.",
            s.profile_hits,
        );
        write_counter(&mut out, "hbmc_tunes_total", "tune() runs completed.", s.tunes);
        write_counter(
            &mut out,
            "hbmc_trace_events_dropped_total",
            "Trace events evicted from the full ring buffer.",
            self.core.obs.trace.dropped(),
        );
        write_counter(
            &mut out,
            "hbmc_leaked_workers_total",
            "Pool workers abandoned by a drain timeout, process-wide.",
            crate::coordinator::pool::leaked_workers(),
        );
        // The breaker family is rendered here rather than from the
        // registry so the worst-state sample (backward-compatible,
        // unlabeled) and the per-handle samples share one TYPE block.
        write_gauge(
            &mut out,
            "hbmc_breaker_state",
            "Circuit-breaker state (0=closed, 1=half-open, 2=open); the unlabeled \
             sample is the worst state across handles.",
            self.core.obs.breaker_state.get(),
        );
        for (id, state) in self.core.breaker_states() {
            out.push_str(&format!("hbmc_breaker_state{{handle=\"{id}\"}} {state}\n"));
        }
        out.push_str(&prometheus::render(&self.core.obs.snapshot()));
        out
    }

    /// Point-in-time copy of the registry-backed metrics (admission
    /// counters, phase counters, and the latency/width histograms with
    /// their [`quantile`](crate::obs::HistogramSnapshot::quantile)
    /// accessors) — the structured counterpart of
    /// [`metrics_text`](SolverService::metrics_text) for in-process
    /// consumers like the benches.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.core.obs.snapshot()
    }

    /// Service health as `(healthy, body)` — what
    /// [`MetricsServer::spawn_with_health`](crate::obs::MetricsServer::spawn_with_health)
    /// serves on `/healthz`. `healthy == false` (HTTP 503) when circuit
    /// breakers exist and every one is open; `true` with a `degraded: …`
    /// body when some breaker is open/half-open or jobs have been shed;
    /// `("ok\n", true)` otherwise.
    pub fn health(&self) -> (bool, String) {
        self.core.health()
    }

    /// The lifecycle-trace ring as a JSON array of
    /// `{"job","stage","t_us","detail"}` events, oldest first. Empty
    /// (`[]`) unless `QueueConfig::trace_sample` is non-zero.
    pub fn trace_json(&self) -> String {
        self.core.obs.trace.to_json()
    }

    /// Human-readable statistics: the [`ServiceStats`] counters plus a
    /// summary row per histogram (count / mean / p50 / p99), rendered with
    /// the same table engine as the bench reports. This is what the CLI
    /// `stats` subcommand prints.
    pub fn stats_text(&self) -> String {
        let s = self.stats();
        let snap = self.metrics_snapshot();
        let mut t = Table::new("service stats", &["metric", "value"]);
        let mut row = |name: &str, value: String| t.push_row(vec![name.to_string(), value]);
        row("matrices", s.matrices.to_string());
        row("plan cache", format!("{}/{}", s.cache.len, s.cache.capacity));
        row("cache hits / misses / evictions", {
            format!("{} / {} / {}", s.cache.hits, s.cache.misses, s.cache.evictions)
        });
        row("plan builds (coalesced)", format!("{} ({})", s.builds, s.coalesced_builds));
        row("solves", s.solves.to_string());
        row("queue depth", s.queue_depth.to_string());
        row("batches (mean width)", format!("{} ({:.2})", s.batches, s.mean_batch_width()));
        row("batched / coalesced rhs", format!("{} / {}", s.batched_rhs, s.coalesced_rhs));
        row("dispatches", s.dispatches.to_string());
        row("profiles (hits)", format!("{} ({})", s.profiles, s.profile_hits));
        row("tunes", s.tunes.to_string());
        row("overloaded rejections", s.overloaded.to_string());
        row("shed (expired at dispatch)", s.shed.to_string());
        let mut out = t.render();
        let mut h = Table::new("histograms", &["histogram", "count", "mean", "p50", "p99"]);
        for (family, label, time) in [
            ("hbmc_queue_wait_microseconds", "queue wait", true),
            ("hbmc_batch_width", "batch width", false),
            ("hbmc_setup_microseconds", "plan setup", true),
            ("hbmc_solve_microseconds", "solve", true),
            ("hbmc_solve_iterations", "iterations", false),
        ] {
            if let Some(hist) = snap.histogram(family) {
                let value = |v: f64| if time { micros(v) } else { format!("{v:.0}") };
                h.push_row(vec![
                    label.to_string(),
                    hist.count.to_string(),
                    value(hist.mean()),
                    value(hist.quantile(0.5).unwrap_or(0) as f64),
                    value(hist.quantile(0.99).unwrap_or(0) as f64),
                ]);
            }
        }
        out.push('\n');
        out.push_str(&h.render());
        out
    }
}

impl Drop for SolverService {
    /// Graceful shutdown: stop accepting jobs, let the dispatcher flush
    /// everything already queued, then join it. Every outstanding
    /// `JobHandle` resolves — queued jobs run (or expire/cancel), none are
    /// abandoned mid-wait. A worker panic mid-solve no longer wedges this
    /// join: the dispatcher catches it, drains the poisoned pool with a
    /// bounded timeout (`Pool::drain`), and continues on a fresh session
    /// (see `crate::resil` and the "Resilience" section of
    /// ARCHITECTURE.md).
    fn drop(&mut self) {
        self.queue.shutdown();
        if let Some(dispatcher) = self.dispatcher.take() {
            let _ = dispatcher.join();
        }
    }
}

impl Default for SolverService {
    fn default() -> Self {
        SolverService::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::job::JobState;
    use crate::config::{OrderingKind, Scale};
    use crate::gen::suite;

    fn tiny_cfg(ordering: OrderingKind) -> SolverConfig {
        SolverConfig { ordering, bs: 8, w: 4, rtol: 1e-7, ..Default::default() }
    }

    #[test]
    fn service_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<SolverService>();
        assert_send_sync::<MatrixHandle>();
        fn assert_send<T: Send>() {}
        assert_send::<JobHandle>();
    }

    #[test]
    fn register_solve_and_stats() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        let o1 = svc.solve(h, &d.b).unwrap();
        let o2 = svc.solve(h, &d.b).unwrap();
        assert!(o1.report.converged);
        assert_eq!(o1.x, o2.x, "same plan + rhs must be deterministic");
        let s = svc.stats();
        assert_eq!(s.matrices, 1);
        assert_eq!(s.builds, 1, "second solve must reuse the cached plan");
        assert_eq!(s.cache.hits, 1);
        assert_eq!(s.solves, 2);
        // Two sequential blocking solves = two dispatched batches of one.
        assert_eq!(s.batches, 2);
        assert_eq!(s.batched_rhs, 2);
        assert_eq!(s.coalesced_rhs, 0);
        assert_eq!(s.queue_depth, 0);
        assert!((s.mean_batch_width() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn submit_poll_wait_lifecycle() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        let job = svc.submit(h, &d.b, &SolveRequest::new()).unwrap();
        assert!(job.id() > 0);
        // Whatever intermediate states we observe, wait() must resolve.
        let state = job.poll();
        assert!(
            matches!(
                state,
                JobState::Queued | JobState::Running | JobState::Succeeded
            ),
            "{state:?}"
        );
        let out = job.wait().unwrap();
        assert!(out.report.converged);
        assert_eq!(svc.stats().solves, 1);
    }

    #[test]
    fn unknown_handle_is_typed() {
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Bmc)).unwrap();
        let d = suite::dataset("thermal2", Scale::Tiny);
        let h = svc.register_matrix(d.matrix.clone());
        svc.unregister_matrix(h).unwrap();
        let err = svc.solve(h, &d.b).unwrap_err();
        assert!(matches!(err, HbmcError::UnknownMatrix(_)), "{err:?}");
        assert!(matches!(svc.unregister_matrix(h), Err(HbmcError::UnknownMatrix(_))));
        // submit validates synchronously, too.
        let err = svc.submit(h, &d.b, &SolveRequest::new()).unwrap_err();
        assert!(matches!(err, HbmcError::UnknownMatrix(_)), "{err:?}");
    }

    #[test]
    fn dimension_mismatch_is_typed_for_solve_and_batch() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        let n = d.matrix.n();
        let err = svc.solve(h, &[1.0, 2.0]).unwrap_err();
        assert!(
            matches!(err, HbmcError::DimensionMismatch { expected, got }
                if expected == n && got == 2),
            "{err:?}"
        );
        // A batch with one bad rhs is rejected before any job is enqueued.
        let err = svc.solve_many(h, &[d.b.clone(), vec![0.0; 3]]).unwrap_err();
        assert!(matches!(err, HbmcError::DimensionMismatch { got: 3, .. }), "{err:?}");
        let s = svc.stats();
        assert_eq!(s.solves, 0, "rejected batch must not run");
        assert_eq!(s.batches, 0, "rejected batch must not even be enqueued");
    }

    #[test]
    fn per_request_config_overrides_use_distinct_plans() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        svc.solve(h, &d.b).unwrap();
        let req = SolveRequest::new().with_config(tiny_cfg(OrderingKind::Bmc));
        svc.solve_with(h, &d.b, &req).unwrap();
        assert_eq!(svc.stats().builds, 2, "different ordering = different plan key");
        // rtol/max_iters overrides do NOT make a new plan.
        svc.solve_with(h, &d.b, &SolveRequest::new().rtol(1e-3)).unwrap();
        assert_eq!(svc.stats().builds, 2);
    }

    #[test]
    fn require_convergence_yields_not_converged() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        let req = SolveRequest::new().max_iters(2).require_convergence();
        let err = svc.solve_with(h, &d.b, &req).unwrap_err();
        assert!(
            matches!(err, HbmcError::NotConverged { iterations: 2, .. }),
            "{err:?}"
        );
        // Without the flag the same request is an Ok non-converged report.
        let out = svc.solve_with(h, &d.b, &SolveRequest::new().max_iters(2)).unwrap();
        assert!(!out.report.converged);
    }

    #[test]
    fn tuned_profile_auto_applies_and_can_be_opted_out() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        // Hand-install a profile (tuning itself is covered in tests/tune.rs):
        // same hardware, different structural choice than the default.
        let profile = TunedProfile {
            fingerprint: d.matrix.fingerprint(),
            hardware: svc.hardware(),
            ordering: OrderingKind::Bmc,
            bs: 8,
            w: 4,
            spmv: crate::config::SpmvKind::Crs,
            sell_sigma: None,
            threads: 1,
            use_intrinsics: true,
            solve_seconds: 1e-3,
            setup_seconds: 1e-2,
            iterations: 10,
            baseline_solve_seconds: 2e-3,
            phase_shares: None,
            created_unix: 0,
        };
        assert!(svc.install_profile(profile.clone()).unwrap());
        assert_eq!(svc.profile(h).unwrap().unwrap().ordering, OrderingKind::Bmc);
        // Default-config solve runs under the profile...
        let out = svc.solve(h, &d.b).unwrap();
        assert!(out.report.converged);
        let label = out.report.plan.config_label;
        assert!(label.starts_with("BMC"), "{label}");
        let s = svc.stats();
        assert_eq!((s.profiles, s.profile_hits), (1, 1));
        // ...opting out runs the service default (a different plan)...
        let raw = svc.solve_with(h, &d.b, &SolveRequest::new().no_profile()).unwrap();
        let label = raw.report.plan.config_label;
        assert!(label.starts_with("HBMC"), "{label}");
        assert_eq!(svc.stats().profile_hits, 1, "opt-out must not count a hit");
        // ...and an explicit override beats the profile without a hit.
        let req = SolveRequest::new().with_config(tiny_cfg(OrderingKind::Mc));
        let over = svc.solve_with(h, &d.b, &req).unwrap();
        let label = over.report.plan.config_label;
        assert!(label.starts_with("MC"), "{label}");
        assert_eq!(svc.stats().profile_hits, 1);
    }

    #[test]
    fn foreign_hardware_profile_is_rejected_not_installed() {
        use crate::tune::SimdLevel;
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        let mut hw = svc.hardware();
        hw.cores += 1; // a different machine
        hw.simd = SimdLevel::Scalar;
        let foreign = TunedProfile {
            fingerprint: d.matrix.fingerprint(),
            hardware: hw,
            ordering: OrderingKind::Bmc,
            bs: 8,
            w: 4,
            spmv: crate::config::SpmvKind::Crs,
            sell_sigma: None,
            threads: 1,
            use_intrinsics: false,
            solve_seconds: 1e-3,
            setup_seconds: 1e-2,
            iterations: 10,
            baseline_solve_seconds: 2e-3,
            phase_shares: None,
            created_unix: 0,
        };
        assert!(!svc.install_profile(foreign).unwrap(), "cross-machine profiles must not install");
        assert_eq!(svc.stats().profiles, 0);
        let out = svc.solve(h, &d.b).unwrap();
        assert!(out.report.plan.config_label.starts_with("HBMC"));
        assert_eq!(svc.stats().profile_hits, 0);
    }

    #[test]
    fn zero_deadline_is_rejected_synchronously() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        let req = SolveRequest::new().deadline(Duration::ZERO);
        let err = svc.submit(h, &d.b, &req).unwrap_err();
        assert!(
            matches!(err, HbmcError::DeadlineExceeded { budget } if budget.is_zero()),
            "{err:?}"
        );
        let err = svc.solve_many_with(h, &[d.b.clone()], &req).unwrap_err();
        assert!(matches!(err, HbmcError::DeadlineExceeded { .. }), "{err:?}");
        let s = svc.stats();
        assert_eq!(s.solves, 0, "a rejected submission must never run");
        assert_eq!(s.batches, 0, "a rejected submission must never be enqueued");
        assert_eq!(s.overloaded, 0, "deadline rejection is not an overload");
    }

    #[test]
    fn metrics_text_covers_every_stats_counter() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        svc.solve(h, &d.b).unwrap();
        let text = svc.metrics_text();
        for family in [
            "hbmc_matrices",
            "hbmc_queue_depth",
            "hbmc_plan_cache_entries",
            "hbmc_plan_cache_capacity",
            "hbmc_profiles_installed",
            "hbmc_plan_cache_hits_total",
            "hbmc_plan_cache_misses_total",
            "hbmc_plan_cache_evictions_total",
            "hbmc_plan_builds_total",
            "hbmc_coalesced_builds_total",
            "hbmc_solves_total",
            "hbmc_batches_total",
            "hbmc_batched_rhs_total",
            "hbmc_coalesced_rhs_total",
            "hbmc_dispatches_total",
            "hbmc_profile_hits_total",
            "hbmc_tunes_total",
            "hbmc_trace_events_dropped_total",
            "hbmc_leaked_workers_total",
            "hbmc_kernel_phase_microseconds",
            "hbmc_barrier_wait_imbalance",
            "hbmc_overloaded_total",
            "hbmc_shed_total",
            "hbmc_retries_total",
            "hbmc_pool_rebuilds_total",
            "hbmc_breaker_state",
            "hbmc_phase_microseconds_total",
            "hbmc_queue_wait_microseconds",
            "hbmc_batch_width",
            "hbmc_setup_microseconds",
            "hbmc_solve_microseconds",
            "hbmc_solve_iterations",
        ] {
            assert!(text.contains(&format!("# TYPE {family} ")), "missing family {family}");
        }
        assert!(text.contains("hbmc_solves_total 1\n"), "{text}");
        assert!(text.contains("hbmc_matrices 1\n"));
        assert!(text.contains("hbmc_overloaded_total{reason=\"queue_depth\"} 0\n"));
        assert!(text.contains("hbmc_solve_microseconds_bucket{le=\"+Inf\"} 1\n"));
        // One solve also fed the phase counters and histograms.
        let snap = svc.metrics_snapshot();
        assert_eq!(snap.histogram("hbmc_solve_microseconds").unwrap().count, 1);
        assert_eq!(snap.histogram("hbmc_batch_width").unwrap().count, 1);
        assert_eq!(snap.histogram("hbmc_queue_wait_microseconds").unwrap().count, 1);
        assert_eq!(snap.histogram("hbmc_setup_microseconds").unwrap().count, 1);
        assert!(snap.counter("hbmc_phase_microseconds_total").unwrap() > 0);
    }

    #[test]
    fn stats_text_and_trace_json_render() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let mut cfg = tiny_cfg(OrderingKind::Hbmc);
        cfg.queue.trace_sample = 1;
        let svc = SolverService::with_config(cfg).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        assert_eq!(svc.trace_json(), "[]", "no jobs traced yet");
        svc.solve(h, &d.b).unwrap();
        let text = svc.stats_text();
        assert!(text.contains("== service stats =="), "{text}");
        assert!(text.contains("solves"));
        assert!(text.contains("overloaded rejections"));
        assert!(text.contains("== histograms =="));
        assert!(text.contains("queue wait"));
        let json = svc.trace_json();
        for stage in ["submitted", "enqueued", "batch_opened", "dispatched", "completed"] {
            assert!(json.contains(&format!("\"stage\":\"{stage}\"")), "{json}");
        }
    }

    #[test]
    fn circuit_breaker_opens_trips_health_and_recovers() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let mut cfg = tiny_cfg(OrderingKind::Hbmc);
        cfg.queue.breaker_threshold = Some(2);
        let svc = SolverService::with_config(cfg).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        assert_eq!(svc.health(), (true, "ok\n".to_string()));
        // Two consecutive typed failures open the breaker (no retry budget,
        // so the stalled solves are final failures).
        let stall = SolveRequest::new().max_iters(1).require_convergence();
        for _ in 0..2 {
            let err = svc.solve_with(h, &d.b, &stall).unwrap_err();
            assert!(matches!(err, HbmcError::NotConverged { .. }), "{err:?}");
        }
        // The next submissions are rejected synchronously and typed; each
        // rejection advances the count-based cooldown toward half-open.
        for _ in 0..2 {
            let err = svc.submit(h, &d.b, &SolveRequest::new()).unwrap_err();
            assert!(
                matches!(err, HbmcError::CircuitOpen { failures: 2, .. }),
                "{err:?}"
            );
        }
        let (healthy, body) = svc.health();
        assert!(!healthy && body.starts_with("unhealthy:"), "{body}");
        let text = svc.metrics_text();
        assert!(text.contains("hbmc_breaker_state 2\n"), "{text}");
        assert!(
            text.contains(&format!("hbmc_breaker_state{{handle=\"{}\"}} 2\n", h.id())),
            "per-handle breaker sample missing: {text}"
        );
        // Half-open now: the single probe is admitted, succeeds, and closes
        // the breaker — service healthy again.
        let out = svc.solve(h, &d.b).unwrap();
        assert!(out.report.converged);
        assert_eq!(svc.health(), (true, "ok\n".to_string()));
        assert!(svc.metrics_text().contains("hbmc_breaker_state 0\n"));
    }

    #[test]
    fn profiled_solve_feeds_kernel_phase_metrics() {
        use crate::obs::metrics::SeriesValue;
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        // An unprofiled solve carries no profile and feeds no phase series.
        let plain = svc.solve(h, &d.b).unwrap();
        assert!(plain.report.profile.is_none());
        let mut req = SolveRequest::new();
        req.options.profile = true;
        let out = svc.solve_with(h, &d.b, &req).unwrap();
        let profile = out.report.profile.as_ref().expect("profiled solve returns a profile");
        assert!(profile.coverage() > 0.0, "recorder must have captured spans");
        // Exactly one observation landed in each of this ordering's phase
        // series (5 phases), and only there.
        let snap = svc.metrics_snapshot();
        let counts: Vec<u64> = snap
            .series
            .iter()
            .filter(|s| s.family == "hbmc_kernel_phase_microseconds")
            .map(|s| match &s.value {
                SeriesValue::Histogram(hist) => hist.count,
                _ => 0,
            })
            .collect();
        assert_eq!(counts.len(), ORDERING_LABELS.len() * PHASE_NAMES.len());
        assert_eq!(counts.iter().sum::<u64>(), PHASE_NAMES.len() as u64);
        let text = svc.metrics_text();
        assert!(text.contains("# TYPE hbmc_kernel_phase_microseconds histogram"), "{text}");
        assert!(text.contains("phase=\"spmv\",ordering=\"hbmc\""), "{text}");
        assert!(text.contains("# TYPE hbmc_barrier_wait_imbalance gauge"), "{text}");
        assert!(text.contains("hbmc_leaked_workers_total"), "{text}");
    }

    #[test]
    fn empty_batch_is_free() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        let rhss: Vec<Vec<f64>> = Vec::new();
        let outs = svc.solve_many(h, &rhss).unwrap();
        assert!(outs.is_empty());
        let s = svc.stats();
        assert_eq!(s.builds, 0, "empty batch must not build a plan");
        assert_eq!(s.cache.misses, 0);
        assert_eq!(s.solves, 0);
        assert_eq!(s.batches, 0, "empty batch must not reach the queue");
    }
}
