//! [`SolverService`] — the thread-safe serving façade, now an
//! **asynchronous job endpoint**.
//!
//! One service owns (a) a registry of matrices behind opaque
//! [`MatrixHandle`]s, (b) the LRU [`PlanCache`] behind an `RwLock` with a
//! per-[`PlanKey`] build gate (concurrent same-key requests trigger exactly
//! one plan build), and (c) a job queue drained by one dispatcher thread
//! (`api::queue`). [`submit`](SolverService::submit) enqueues one
//! right-hand side and returns a [`JobHandle`] immediately; the dispatcher
//! micro-batches compatible jobs onto one session, so concurrent
//! single-RHS traffic shares one plan checkout and one warmed-up pool
//! instead of paying per-request setup. The blocking
//! [`solve`](SolverService::solve) / [`solve_many`](SolverService::solve_many)
//! calls are thin submit + wait wrappers over the same queue, so existing
//! callers keep working — and transparently coalesce with each other.
//!
//! Dropping the service shuts the queue down gracefully: no new
//! submissions, everything already queued is flushed, then the dispatcher
//! thread is joined.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::config::SolverConfig;
use crate::coordinator::driver::SolveOptions;
use crate::coordinator::session::{CacheStats, PlanCache, PlanKey, SolveOutput, SolveSession};
use crate::error::{HbmcError, Result};
use crate::solver::plan::SolverPlan;
use crate::sparse::csr::Csr;
use crate::tune::{tune_matrix, HardwareSignature, ProfileStore, TuneOptions, TunedProfile};

use super::job::{JobCore, JobHandle};
use super::queue::{dispatcher_loop, BatchKey, JobQueue, QueuedJob};

/// Opaque ticket for a matrix registered with a [`SolverService`]. Cheap to
/// copy and share across threads. Ids are allocated from one process-wide
/// counter, so a handle presented to a service other than its issuer can
/// never alias a different matrix — it fails with
/// [`HbmcError::UnknownMatrix`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MatrixHandle(u64);

impl MatrixHandle {
    /// The raw registry id (diagnostics, log correlation).
    pub fn id(&self) -> u64 {
        self.0
    }
}

/// Process-wide handle allocator (see [`MatrixHandle`]). Relaxed suffices:
/// ids only need to be unique, which atomicity alone guarantees.
static NEXT_MATRIX_ID: AtomicU64 = AtomicU64::new(1);

/// A registry entry: the shared matrix plus its content fingerprint,
/// hashed once at registration (an O(nnz) scan) rather than per request.
#[derive(Clone)]
pub(crate) struct Registered {
    pub(crate) matrix: Arc<Csr>,
    pub(crate) fingerprint: u64,
}

/// Per-request overrides layered on the service's default configuration.
///
/// `config` swaps the *structural* configuration (ordering, bs, w, storage
/// — a different [`PlanKey`], hence possibly a different cached plan);
/// `options` carries the per-solve knobs (rtol/max_iters overrides,
/// history, solution copy) that never invalidate a plan; `deadline` bounds
/// how long a submitted job may sit in the queue before it is failed with
/// [`HbmcError::DeadlineExceeded`] instead of dispatched.
#[derive(Debug, Clone, Default)]
pub struct SolveRequest {
    /// Structural config for this request; `None` = the service default.
    /// (The `queue` field of an override is ignored — dispatcher tuning is
    /// service-level.)
    pub config: Option<SolverConfig>,
    /// Per-solve options (tolerance/iteration overrides, history, …).
    pub options: SolveOptions,
    /// Turn a non-converged result into [`HbmcError::NotConverged`]
    /// instead of an `Ok` report with `converged == false`.
    pub require_convergence: bool,
    /// Maximum time the job may wait in the queue before dispatch. Checked
    /// when the dispatcher reaches the job: an expired job never runs; a
    /// job that started before expiry always finishes.
    pub deadline: Option<Duration>,
    /// Opt out of automatic tuned-profile application for this request
    /// (see [`SolverService::tune`]): solve under the service default even
    /// when a profile is installed for the matrix. Irrelevant when
    /// `config` is set — an explicit override always wins.
    pub skip_profile: bool,
}

impl SolveRequest {
    pub fn new() -> SolveRequest {
        SolveRequest::default()
    }

    /// Use this structural config (a different plan-cache key) instead of
    /// the service default.
    pub fn with_config(mut self, cfg: SolverConfig) -> SolveRequest {
        self.config = Some(cfg);
        self
    }

    /// Override the convergence tolerance for this request only.
    pub fn rtol(mut self, rtol: f64) -> SolveRequest {
        self.options.rtol = Some(rtol);
        self
    }

    /// Override the iteration cap for this request only.
    pub fn max_iters(mut self, max_iters: usize) -> SolveRequest {
        self.options.max_iters = Some(max_iters);
        self
    }

    /// Record the per-iteration residual history.
    pub fn record_history(mut self) -> SolveRequest {
        self.options.record_history = true;
        self
    }

    /// Copy the solution vector into the report.
    pub fn return_solution(mut self) -> SolveRequest {
        self.options.return_solution = true;
        self
    }

    /// Fail with [`HbmcError::NotConverged`] when the cap is reached.
    pub fn require_convergence(mut self) -> SolveRequest {
        self.require_convergence = true;
        self
    }

    /// Fail the job with [`HbmcError::DeadlineExceeded`] if it is still
    /// queued `budget` after submission (see the field docs).
    pub fn deadline(mut self, budget: Duration) -> SolveRequest {
        self.deadline = Some(budget);
        self
    }

    /// Solve under the service default even when a tuned profile is
    /// installed for the matrix (per-request opt-out of auto-application).
    pub fn no_profile(mut self) -> SolveRequest {
        self.skip_profile = true;
        self
    }
}

/// Point-in-time service counters: registry size, plan-cache counters,
/// build/coalescing behaviour under concurrency, and the job queue's
/// batching statistics.
#[derive(Debug, Clone, Copy)]
pub struct ServiceStats {
    /// Matrices currently registered.
    pub matrices: usize,
    /// Plan-cache snapshot (len/capacity/hits/misses/evictions).
    pub cache: CacheStats,
    /// Plans actually built by this service (== cache misses).
    pub builds: u64,
    /// Requests that waited on another thread's in-flight build instead of
    /// building themselves.
    pub coalesced_builds: u64,
    /// Solves completed through the service.
    pub solves: u64,
    /// Jobs currently waiting in the queue (not yet dispatched).
    pub queue_depth: usize,
    /// Micro-batches the dispatcher has run (each = one plan checkout +
    /// one session).
    pub batches: u64,
    /// Total right-hand sides dispatched across all batches.
    pub batched_rhs: u64,
    /// Right-hand sides that rode in a batch of width ≥ 2 — i.e. requests
    /// that shared a session with at least one other request.
    pub coalesced_rhs: u64,
    /// Total `Pool::run` dispatches across all solves completed through
    /// the job queue. With the fused single-dispatch loop this equals
    /// `solves`; the legacy loop pays ~3 per CG iteration. (Solves on
    /// queue-bypass `session()` handles are not counted.)
    pub dispatches: u64,
    /// Tuned profiles currently installed (via [`SolverService::tune`],
    /// [`install_profile`](SolverService::install_profile) or an attached
    /// store).
    pub profiles: usize,
    /// Requests that ran under an auto-applied tuned profile (no explicit
    /// config override, profile present, not opted out).
    pub profile_hits: u64,
    /// [`SolverService::tune`] runs completed on this service.
    pub tunes: u64,
}

impl ServiceStats {
    /// Mean dispatched batch width (`batched_rhs / batches`); 0 before the
    /// first batch.
    pub fn mean_batch_width(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_rhs as f64 / self.batches as f64
        }
    }
}

// Lock helpers: the service never panics while holding a lock on the hot
// path, but a poisoned lock must not cascade — recover the guard.
fn wlock<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

fn rlock<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

pub(crate) fn mlock<T>(l: &Mutex<T>) -> MutexGuard<'_, T> {
    l.lock().unwrap_or_else(PoisonError::into_inner)
}

/// The service state shared between request threads and the dispatcher
/// thread: registry, plan cache + build gates, and the statistics counters.
pub(crate) struct ServiceCore {
    default_cfg: SolverConfig,
    /// The host this service runs on — the hardware half of every profile
    /// key (detected once at construction).
    hardware: HardwareSignature,
    matrices: RwLock<HashMap<u64, Registered>>,
    cache: RwLock<PlanCache>,
    /// Installed tuned profiles by matrix fingerprint. Only profiles
    /// matching `hardware` are ever admitted, so the fingerprint alone
    /// keys this map.
    profiles: RwLock<HashMap<u64, TunedProfile>>,
    /// Store file `tune` persists into (set by `attach_profile_store`).
    profile_store: Mutex<Option<PathBuf>>,
    /// Per-key build gates: the map lock is held only to look up/insert a
    /// gate; the gate itself is held for the duration of one plan build.
    building: Mutex<HashMap<PlanKey, Arc<Mutex<()>>>>,
    // Monotonic statistics counters. `Relaxed` is deliberate and
    // sufficient: each is independently monotonic and read only for
    // reporting — nothing establishes happens-before through them (the
    // data they describe synchronizes via the registry/cache locks and the
    // job-state mutexes). They are not synchronization points; `SeqCst`
    // would only add fences on the hot path.
    builds: AtomicU64,
    coalesced: AtomicU64,
    solves: AtomicU64,
    dispatches: AtomicU64,
    profile_hits: AtomicU64,
    tunes: AtomicU64,
}

impl ServiceCore {
    pub(crate) fn registered(&self, handle: MatrixHandle) -> Result<Registered> {
        rlock(&self.matrices)
            .get(&handle.0)
            .cloned()
            .ok_or_else(|| HbmcError::UnknownMatrix(format!("handle #{}", handle.0)))
    }

    /// The tuned config for a matrix, if a profile is installed: the
    /// profile's structural choice overlaid on the service default (the
    /// default's convergence contract is preserved — see
    /// `TunedProfile::apply_to`).
    fn tuned_config(&self, fingerprint: u64) -> Option<SolverConfig> {
        rlock(&self.profiles).get(&fingerprint).map(|p| p.apply_to(&self.default_cfg))
    }

    /// Get-or-build with single-build coalescing (see `plan` on the
    /// service). Called by request threads and by the dispatcher.
    pub(crate) fn plan_for(&self, reg: &Registered, cfg: &SolverConfig) -> Result<Arc<SolverPlan>> {
        let key = PlanKey::from_fingerprint(reg.fingerprint, cfg);
        // Fast path: cached (write lock — `get` touches the LRU clock).
        if let Some(plan) = wlock(&self.cache).get(&key) {
            return Ok(plan);
        }
        // Slow path: take this key's build gate so one thread builds while
        // the rest wait here, not in a duplicate factorization.
        let gate = mlock(&self.building).entry(key.clone()).or_default().clone();
        let permit = mlock(&gate);
        // Re-check under the gate: whoever held it before us has inserted.
        if let Some(plan) = wlock(&self.cache).get(&key) {
            self.coalesced.fetch_add(1, AtomicOrdering::Relaxed);
            drop(permit);
            self.release_gate(&key, &gate);
            return Ok(plan);
        }
        let result = SolverPlan::build(&reg.matrix, cfg).map(|plan| {
            let plan = Arc::new(plan);
            self.builds.fetch_add(1, AtomicOrdering::Relaxed);
            wlock(&self.cache).insert(key.clone(), plan.clone());
            plan
        });
        drop(permit);
        self.release_gate(&key, &gate);
        result
    }

    /// Retire a build gate once no other thread is waiting on it. Removing
    /// only when we hold the map's sole outside reference keeps the gate
    /// stable while contended — every concurrent requester for a key always
    /// serializes on the *same* mutex, so a rebuilt (failed or evicted) key
    /// can never be built twice at once — while still letting idle entries
    /// be reclaimed instead of accumulating per distinct key.
    fn release_gate(&self, key: &PlanKey, gate: &Arc<Mutex<()>>) {
        let mut map = mlock(&self.building);
        // Strong refs on the entry: the map's + ours (`gate`) + one per
        // thread that has fetched it and not yet released. <= 2 means
        // nobody else can be waiting; a later requester must go through
        // the map lock we hold, so the count cannot grow under us.
        let retire = map
            .get(key)
            .is_some_and(|current| Arc::ptr_eq(current, gate) && Arc::strong_count(current) <= 2);
        if retire {
            map.remove(key);
        }
    }

    /// Count one completed solve (called by the dispatcher per rhs).
    pub(crate) fn note_solve(&self) {
        self.solves.fetch_add(1, AtomicOrdering::Relaxed);
    }

    /// Drop a plan from the cache outright (poisoned-batch recovery: a
    /// solver panic implicates the plan a worker was reading when it
    /// died). The next request for this `PlanKey` rebuilds from the
    /// matrix instead of re-checking out a suspect plan; the per-key
    /// build gate still guarantees the rebuild happens exactly once under
    /// concurrency.
    pub(crate) fn evict_plan(&self, key: &PlanKey) -> bool {
        wlock(&self.cache).remove(key).is_some()
    }

    /// Accumulate a completed solve's pool-dispatch count.
    pub(crate) fn note_dispatches(&self, n: u64) {
        self.dispatches.fetch_add(n, AtomicOrdering::Relaxed);
    }
}

/// Thread-safe solve endpoint; see module docs. `Send + Sync` — share one
/// instance behind an `Arc` across all request threads.
pub struct SolverService {
    core: Arc<ServiceCore>,
    queue: Arc<JobQueue>,
    dispatcher: Option<JoinHandle<()>>,
}

/// Default plan-cache capacity (`SolverService::new`).
pub const DEFAULT_PLAN_CAPACITY: usize = 8;

impl SolverService {
    /// Service with the default configuration and plan-cache capacity.
    pub fn new() -> SolverService {
        SolverService::with_capacity(SolverConfig::default(), DEFAULT_PLAN_CAPACITY)
            .expect("default service must construct")
    }

    /// Service whose `solve(handle, b)` uses `default_cfg`; fails fast on
    /// an invalid config rather than at first request.
    pub fn with_config(default_cfg: SolverConfig) -> Result<SolverService> {
        SolverService::with_capacity(default_cfg, DEFAULT_PLAN_CAPACITY)
    }

    /// Full constructor: default config + plan-cache capacity (≥ 1). Also
    /// spawns the dispatcher thread, tuned by `default_cfg.queue`.
    pub fn with_capacity(default_cfg: SolverConfig, capacity: usize) -> Result<SolverService> {
        default_cfg.validate()?;
        if capacity == 0 {
            return Err(HbmcError::invalid_config("plan cache capacity must be >= 1"));
        }
        let queue_cfg = default_cfg.queue;
        let core = Arc::new(ServiceCore {
            default_cfg,
            hardware: HardwareSignature::detect(),
            matrices: RwLock::new(HashMap::new()),
            cache: RwLock::new(PlanCache::new(capacity)),
            profiles: RwLock::new(HashMap::new()),
            profile_store: Mutex::new(None),
            building: Mutex::new(HashMap::new()),
            builds: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            solves: AtomicU64::new(0),
            dispatches: AtomicU64::new(0),
            profile_hits: AtomicU64::new(0),
            tunes: AtomicU64::new(0),
        });
        let queue = Arc::new(JobQueue::new(queue_cfg));
        let dispatcher = {
            let (queue, core) = (Arc::clone(&queue), Arc::clone(&core));
            std::thread::Builder::new()
                .name("hbmc-dispatcher".into())
                .spawn(move || dispatcher_loop(queue, core))
                .map_err(|e| HbmcError::io("spawning the service dispatcher thread", e))?
        };
        Ok(SolverService { core, queue, dispatcher: Some(dispatcher) })
    }

    /// The configuration used when a request carries no override.
    pub fn default_config(&self) -> &SolverConfig {
        &self.core.default_cfg
    }

    /// Register a matrix; the returned handle addresses it in every later
    /// call. Registration never builds a plan — that happens lazily (and
    /// exactly once per distinct config) at first solve.
    pub fn register_matrix(&self, a: Csr) -> MatrixHandle {
        self.register_matrix_arc(Arc::new(a))
    }

    /// Zero-copy registration for callers that already share the matrix.
    /// The matrix is fingerprinted here, once, so later plan-cache lookups
    /// never rescan it.
    pub fn register_matrix_arc(&self, a: Arc<Csr>) -> MatrixHandle {
        let id = NEXT_MATRIX_ID.fetch_add(1, AtomicOrdering::Relaxed);
        let entry = Registered { fingerprint: a.fingerprint(), matrix: a };
        wlock(&self.core.matrices).insert(id, entry);
        MatrixHandle(id)
    }

    /// Drop a matrix from the registry. Cached plans for it age out of the
    /// LRU naturally; queued jobs captured their registry entry at submit
    /// time and are unaffected, as are in-flight solves holding the plan.
    pub fn unregister_matrix(&self, handle: MatrixHandle) -> Result<()> {
        match wlock(&self.core.matrices).remove(&handle.0) {
            Some(_) => Ok(()),
            None => Err(HbmcError::UnknownMatrix(format!("handle #{}", handle.0))),
        }
    }

    /// The registered matrix behind `handle`.
    pub fn matrix(&self, handle: MatrixHandle) -> Result<Arc<Csr>> {
        Ok(self.core.registered(handle)?.matrix)
    }

    /// Get-or-build the plan for `(handle, cfg)` with single-build
    /// coalescing (concurrent same-key requests produce exactly one
    /// `SolverPlan::build`).
    pub fn plan(&self, handle: MatrixHandle, cfg: &SolverConfig) -> Result<Arc<SolverPlan>> {
        cfg.validate()?;
        let reg = self.core.registered(handle)?;
        self.core.plan_for(&reg, cfg)
    }

    /// Open a [`SolveSession`] on the (cached or freshly built) plan for
    /// `(handle, cfg)`, with the request's pool width and tolerances — the
    /// power-user path that bypasses the job queue for callers that want
    /// to hold one session across a burst of solves themselves.
    pub fn session(&self, handle: MatrixHandle, cfg: &SolverConfig) -> Result<SolveSession> {
        let plan = self.plan(handle, cfg)?;
        Ok(SolveSession::for_request(plan, cfg))
    }

    /// Enqueue one right-hand side and return immediately with a
    /// [`JobHandle`] (poll / wait / cancel; see `api::job`).
    ///
    /// Validation (handle, config, rhs dimension) happens here, so a
    /// malformed request fails synchronously with a typed error and never
    /// occupies the queue. The dispatcher micro-batches this job with any
    /// other queued jobs that share its plan and session parameters —
    /// concurrent submitters against one matrix share one session instead
    /// of spinning up N.
    pub fn submit(
        &self,
        handle: MatrixHandle,
        rhs: &[f64],
        req: &SolveRequest,
    ) -> Result<JobHandle> {
        let reg = self.core.registered(handle)?;
        let (cfg, from_profile) = self.effective_config(&reg, req);
        cfg.validate()?;
        let n = reg.matrix.n();
        if rhs.len() != n {
            return Err(HbmcError::DimensionMismatch { expected: n, got: rhs.len() });
        }
        if from_profile {
            self.core.profile_hits.fetch_add(1, AtomicOrdering::Relaxed);
        }
        Ok(self.enqueue(&reg, &cfg, rhs, req))
    }

    /// The configuration a request solves under: explicit override >
    /// auto-applied tuned profile (unless the request opted out) >
    /// service default. The boolean reports a profile application
    /// (`ServiceStats::profile_hits`). `SolverConfig` is a small all-`Copy`
    /// struct, so the clone is cheaper than the registry lookup before it.
    fn effective_config(&self, reg: &Registered, req: &SolveRequest) -> (SolverConfig, bool) {
        if let Some(cfg) = &req.config {
            return (cfg.clone(), false);
        }
        if !req.skip_profile {
            if let Some(cfg) = self.core.tuned_config(reg.fingerprint) {
                return (cfg, true);
            }
        }
        (self.core.default_cfg.clone(), false)
    }

    /// Infallible enqueue for inputs already validated by the caller
    /// (`submit` per request; `solve_many_with` once for a whole batch).
    fn enqueue(
        &self,
        reg: &Registered,
        cfg: &SolverConfig,
        rhs: &[f64],
        req: &SolveRequest,
    ) -> JobHandle {
        let key = BatchKey::new(PlanKey::from_fingerprint(reg.fingerprint, cfg), cfg);
        let core = JobCore::new(req.deadline);
        self.queue.push(QueuedJob {
            core: Arc::clone(&core),
            key,
            rhs: rhs.to_vec(),
            cfg: cfg.clone(),
            options: req.options.clone(),
            require_convergence: req.require_convergence,
            reg: reg.clone(),
        });
        JobHandle::new(core)
    }

    /// Solve `A x = b` under the service's default configuration.
    ///
    /// A thin [`submit`](SolverService::submit) + wait wrapper: the call
    /// blocks, but the work rides the job queue, so simultaneous blocking
    /// callers against the same matrix still coalesce into shared batches.
    pub fn solve(&self, handle: MatrixHandle, b: &[f64]) -> Result<SolveOutput> {
        self.solve_with(handle, b, &SolveRequest::default())
    }

    /// Solve with per-request overrides (submit + wait; see
    /// [`solve`](SolverService::solve)).
    pub fn solve_with(
        &self,
        handle: MatrixHandle,
        b: &[f64],
        req: &SolveRequest,
    ) -> Result<SolveOutput> {
        self.submit(handle, b, req)?.wait()
    }

    /// Batched serving: all right-hand sides are submitted up front and
    /// dispatched on shared sessions. Results are index-aligned with
    /// `rhss`. An empty slice returns `Ok(vec![])` without touching the
    /// queue, the plan cache, or a session.
    pub fn solve_many<B: AsRef<[f64]>>(
        &self,
        handle: MatrixHandle,
        rhss: &[B],
    ) -> Result<Vec<SolveOutput>> {
        self.solve_many_with(handle, rhss, &SolveRequest::default())
    }

    /// Batched serving with per-request overrides (applied to every rhs).
    ///
    /// Dimension checks run up front, so a malformed batch is rejected
    /// before any job is enqueued. The batch result is all-or-nothing:
    /// with [`require_convergence`](SolveRequest::require_convergence),
    /// the first rhs that stalls fails the call, completed outputs are
    /// discarded, and the not-yet-dispatched remainder is cancelled
    /// (already-running rhss finish, unobserved) — solve rhss
    /// individually when partial results matter.
    pub fn solve_many_with<B: AsRef<[f64]>>(
        &self,
        handle: MatrixHandle,
        rhss: &[B],
        req: &SolveRequest,
    ) -> Result<Vec<SolveOutput>> {
        if rhss.is_empty() {
            return Ok(Vec::new());
        }
        let reg = self.core.registered(handle)?;
        let (cfg, from_profile) = self.effective_config(&reg, req);
        cfg.validate()?;
        let n = reg.matrix.n();
        // Reject every malformed rhs up front — a batch must not enqueue
        // (let alone run) halfway before tripping on rhs k.
        for b in rhss {
            let got = b.as_ref().len();
            if got != n {
                return Err(HbmcError::DimensionMismatch { expected: n, got });
            }
        }
        // Everything is validated; enqueue without re-checking per rhs.
        if from_profile {
            self.core.profile_hits.fetch_add(rhss.len() as u64, AtomicOrdering::Relaxed);
        }
        let jobs: Vec<JobHandle> =
            rhss.iter().map(|b| self.enqueue(&reg, &cfg, b.as_ref(), req)).collect();
        let mut outs = Vec::with_capacity(jobs.len());
        let mut jobs = jobs.into_iter();
        while let Some(job) = jobs.next() {
            match job.wait() {
                Ok(out) => outs.push(out),
                Err(e) => {
                    // The batch result is discarded anyway — shed the
                    // not-yet-dispatched remainder instead of letting the
                    // dispatcher solve rhss nobody can observe. (Running
                    // jobs still finish; cancel is queued-only.)
                    for job in jobs {
                        job.cancel();
                    }
                    return Err(e);
                }
            }
        }
        Ok(outs)
    }

    /// The hardware signature this service detected at construction — the
    /// machine half of every profile key it will accept.
    pub fn hardware(&self) -> HardwareSignature {
        self.core.hardware
    }

    /// Search the valid configuration space for the registered matrix on
    /// this machine (see [`crate::tune`]), install the winning
    /// [`TunedProfile`] so subsequent default-config requests auto-apply
    /// it, and persist it to the attached store (if any;
    /// [`attach_profile_store`](SolverService::attach_profile_store)).
    ///
    /// The search solves against the deterministic representative
    /// right-hand side `A·1` — tuning measures kernel shape, which is
    /// rhs-independent. The incumbent (the service default config) always
    /// competes in the final round, so the returned profile's score is
    /// never worse than the default's on the same measurements.
    ///
    /// Runs synchronously on the caller's thread (it is a measurement, not
    /// a job — riding the queue would let production traffic perturb the
    /// timings and vice versa). Expect seconds of wall time for real
    /// matrices; tune at deploy/registration time, not per request.
    pub fn tune(&self, handle: MatrixHandle, opts: &TuneOptions) -> Result<TunedProfile> {
        let reg = self.core.registered(handle)?;
        let n = reg.matrix.n();
        let ones = vec![1.0; n];
        let mut b = vec![0.0; n];
        reg.matrix.mul_vec(&ones, &mut b);
        let outcome = tune_matrix(&reg.matrix, &b, &self.core.default_cfg, opts)?;
        let profile = outcome.profile;
        // Every fallible step runs before any state change, so an Err
        // return means "nothing happened" — no half-applied tune where the
        // in-memory profile is live but the store write failed (or vice
        // versa).
        if profile.hardware != self.core.hardware {
            // tune_matrix detects the hardware at measurement time; if it
            // no longer matches the signature this service was built under
            // (e.g. a cgroup CPU-quota change moved available_parallelism),
            // the profile is keyed to a machine this service will never
            // match — installing nothing and returning Ok would make
            // tuning look active while profile_hits stays 0 forever.
            return Err(HbmcError::Internal(format!(
                "hardware signature changed during tuning ({} -> {}); profile not installed",
                self.core.hardware, profile.hardware
            )));
        }
        profile.apply_to(&self.core.default_cfg).validate()?;
        // The mutex is held across the whole open → put → save
        // read-modify-write: two concurrent tune() calls (different
        // matrices, same store) must not interleave and lose each other's
        // profile on disk. Tuning is rare and already seconds-long, so
        // serializing the file update is free.
        let store_guard = mlock(&self.core.profile_store);
        if let Some(path) = store_guard.as_ref() {
            let mut store = ProfileStore::open(path)?;
            store.put(profile.clone());
            store.save()?;
        }
        drop(store_guard);
        wlock(&self.core.profiles).insert(profile.fingerprint, profile.clone());
        self.core.tunes.fetch_add(1, AtomicOrdering::Relaxed);
        Ok(profile)
    }

    /// Install a tuned profile for auto-application. Returns `Ok(false)`
    /// (not installed) when the profile was tuned on different hardware —
    /// the paper's cross-machine result is exactly that such a transplant
    /// mis-tunes — and [`HbmcError::InvalidConfig`] when the profile's
    /// structural choice does not validate against the service default.
    pub fn install_profile(&self, profile: TunedProfile) -> Result<bool> {
        if profile.hardware != self.core.hardware {
            return Ok(false);
        }
        profile.apply_to(&self.core.default_cfg).validate()?;
        wlock(&self.core.profiles).insert(profile.fingerprint, profile);
        Ok(true)
    }

    /// Bind a [`ProfileStore`] file to this service: load it now
    /// (installing every profile that matches this machine and validates;
    /// others are skipped) and persist future [`tune`](SolverService::tune)
    /// results into it. Returns the number of profiles installed. A
    /// missing file is an empty store; a corrupt one is
    /// [`HbmcError::Parse`].
    pub fn attach_profile_store(&self, path: impl AsRef<Path>) -> Result<usize> {
        let path = path.as_ref();
        let store = ProfileStore::open(path)?;
        *mlock(&self.core.profile_store) = Some(path.to_path_buf());
        let mut installed = 0;
        for profile in store.iter() {
            if self.install_profile(profile.clone()).unwrap_or(false) {
                installed += 1;
            }
        }
        Ok(installed)
    }

    /// The installed profile for a registered matrix, if any.
    pub fn profile(&self, handle: MatrixHandle) -> Result<Option<TunedProfile>> {
        let reg = self.core.registered(handle)?;
        Ok(rlock(&self.core.profiles).get(&reg.fingerprint).cloned())
    }

    /// Counters: registry size, cache hits/misses/evictions, coalesced
    /// builds, solves served, and the queue's batching statistics.
    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            matrices: rlock(&self.core.matrices).len(),
            cache: rlock(&self.core.cache).stats(),
            builds: self.core.builds.load(AtomicOrdering::Relaxed),
            coalesced_builds: self.core.coalesced.load(AtomicOrdering::Relaxed),
            solves: self.core.solves.load(AtomicOrdering::Relaxed),
            queue_depth: self.queue.depth(),
            batches: self.queue.batches(),
            batched_rhs: self.queue.batched_rhs(),
            coalesced_rhs: self.queue.coalesced_rhs(),
            dispatches: self.core.dispatches.load(AtomicOrdering::Relaxed),
            profiles: rlock(&self.core.profiles).len(),
            profile_hits: self.core.profile_hits.load(AtomicOrdering::Relaxed),
            tunes: self.core.tunes.load(AtomicOrdering::Relaxed),
        }
    }
}

impl Drop for SolverService {
    /// Graceful shutdown: stop accepting jobs, let the dispatcher flush
    /// everything already queued, then join it. Every outstanding
    /// `JobHandle` resolves — queued jobs run (or expire/cancel), none are
    /// abandoned mid-wait — with one caveat: if a multi-threaded pool was
    /// wedged by a mid-color-loop worker panic (the residual gap
    /// documented in `pool.rs`), the dispatcher is stuck inside that solve
    /// and this join inherits the hang rather than abandoning the thread.
    fn drop(&mut self) {
        self.queue.shutdown();
        if let Some(dispatcher) = self.dispatcher.take() {
            let _ = dispatcher.join();
        }
    }
}

impl Default for SolverService {
    fn default() -> Self {
        SolverService::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::job::JobState;
    use crate::config::{OrderingKind, Scale};
    use crate::gen::suite;

    fn tiny_cfg(ordering: OrderingKind) -> SolverConfig {
        SolverConfig { ordering, bs: 8, w: 4, rtol: 1e-7, ..Default::default() }
    }

    #[test]
    fn service_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<SolverService>();
        assert_send_sync::<MatrixHandle>();
        fn assert_send<T: Send>() {}
        assert_send::<JobHandle>();
    }

    #[test]
    fn register_solve_and_stats() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        let o1 = svc.solve(h, &d.b).unwrap();
        let o2 = svc.solve(h, &d.b).unwrap();
        assert!(o1.report.converged);
        assert_eq!(o1.x, o2.x, "same plan + rhs must be deterministic");
        let s = svc.stats();
        assert_eq!(s.matrices, 1);
        assert_eq!(s.builds, 1, "second solve must reuse the cached plan");
        assert_eq!(s.cache.hits, 1);
        assert_eq!(s.solves, 2);
        // Two sequential blocking solves = two dispatched batches of one.
        assert_eq!(s.batches, 2);
        assert_eq!(s.batched_rhs, 2);
        assert_eq!(s.coalesced_rhs, 0);
        assert_eq!(s.queue_depth, 0);
        assert!((s.mean_batch_width() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn submit_poll_wait_lifecycle() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        let job = svc.submit(h, &d.b, &SolveRequest::new()).unwrap();
        assert!(job.id() > 0);
        // Whatever intermediate states we observe, wait() must resolve.
        let state = job.poll();
        assert!(
            matches!(
                state,
                JobState::Queued | JobState::Running | JobState::Succeeded
            ),
            "{state:?}"
        );
        let out = job.wait().unwrap();
        assert!(out.report.converged);
        assert_eq!(svc.stats().solves, 1);
    }

    #[test]
    fn unknown_handle_is_typed() {
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Bmc)).unwrap();
        let d = suite::dataset("thermal2", Scale::Tiny);
        let h = svc.register_matrix(d.matrix.clone());
        svc.unregister_matrix(h).unwrap();
        let err = svc.solve(h, &d.b).unwrap_err();
        assert!(matches!(err, HbmcError::UnknownMatrix(_)), "{err:?}");
        assert!(matches!(svc.unregister_matrix(h), Err(HbmcError::UnknownMatrix(_))));
        // submit validates synchronously, too.
        let err = svc.submit(h, &d.b, &SolveRequest::new()).unwrap_err();
        assert!(matches!(err, HbmcError::UnknownMatrix(_)), "{err:?}");
    }

    #[test]
    fn dimension_mismatch_is_typed_for_solve_and_batch() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        let n = d.matrix.n();
        let err = svc.solve(h, &[1.0, 2.0]).unwrap_err();
        assert!(
            matches!(err, HbmcError::DimensionMismatch { expected, got }
                if expected == n && got == 2),
            "{err:?}"
        );
        // A batch with one bad rhs is rejected before any job is enqueued.
        let err = svc.solve_many(h, &[d.b.clone(), vec![0.0; 3]]).unwrap_err();
        assert!(matches!(err, HbmcError::DimensionMismatch { got: 3, .. }), "{err:?}");
        let s = svc.stats();
        assert_eq!(s.solves, 0, "rejected batch must not run");
        assert_eq!(s.batches, 0, "rejected batch must not even be enqueued");
    }

    #[test]
    fn per_request_config_overrides_use_distinct_plans() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        svc.solve(h, &d.b).unwrap();
        let req = SolveRequest::new().with_config(tiny_cfg(OrderingKind::Bmc));
        svc.solve_with(h, &d.b, &req).unwrap();
        assert_eq!(svc.stats().builds, 2, "different ordering = different plan key");
        // rtol/max_iters overrides do NOT make a new plan.
        svc.solve_with(h, &d.b, &SolveRequest::new().rtol(1e-3)).unwrap();
        assert_eq!(svc.stats().builds, 2);
    }

    #[test]
    fn require_convergence_yields_not_converged() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        let req = SolveRequest::new().max_iters(2).require_convergence();
        let err = svc.solve_with(h, &d.b, &req).unwrap_err();
        assert!(
            matches!(err, HbmcError::NotConverged { iterations: 2, .. }),
            "{err:?}"
        );
        // Without the flag the same request is an Ok non-converged report.
        let out = svc.solve_with(h, &d.b, &SolveRequest::new().max_iters(2)).unwrap();
        assert!(!out.report.converged);
    }

    #[test]
    fn tuned_profile_auto_applies_and_can_be_opted_out() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        // Hand-install a profile (tuning itself is covered in tests/tune.rs):
        // same hardware, different structural choice than the default.
        let profile = TunedProfile {
            fingerprint: d.matrix.fingerprint(),
            hardware: svc.hardware(),
            ordering: OrderingKind::Bmc,
            bs: 8,
            w: 4,
            spmv: crate::config::SpmvKind::Crs,
            sell_sigma: None,
            threads: 1,
            use_intrinsics: true,
            solve_seconds: 1e-3,
            setup_seconds: 1e-2,
            iterations: 10,
            baseline_solve_seconds: 2e-3,
            created_unix: 0,
        };
        assert!(svc.install_profile(profile.clone()).unwrap());
        assert_eq!(svc.profile(h).unwrap().unwrap().ordering, OrderingKind::Bmc);
        // Default-config solve runs under the profile...
        let out = svc.solve(h, &d.b).unwrap();
        assert!(out.report.converged);
        let label = out.report.plan.config_label;
        assert!(label.starts_with("BMC"), "{label}");
        let s = svc.stats();
        assert_eq!((s.profiles, s.profile_hits), (1, 1));
        // ...opting out runs the service default (a different plan)...
        let raw = svc.solve_with(h, &d.b, &SolveRequest::new().no_profile()).unwrap();
        let label = raw.report.plan.config_label;
        assert!(label.starts_with("HBMC"), "{label}");
        assert_eq!(svc.stats().profile_hits, 1, "opt-out must not count a hit");
        // ...and an explicit override beats the profile without a hit.
        let req = SolveRequest::new().with_config(tiny_cfg(OrderingKind::Mc));
        let over = svc.solve_with(h, &d.b, &req).unwrap();
        let label = over.report.plan.config_label;
        assert!(label.starts_with("MC"), "{label}");
        assert_eq!(svc.stats().profile_hits, 1);
    }

    #[test]
    fn foreign_hardware_profile_is_rejected_not_installed() {
        use crate::tune::SimdLevel;
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        let mut hw = svc.hardware();
        hw.cores += 1; // a different machine
        hw.simd = SimdLevel::Scalar;
        let foreign = TunedProfile {
            fingerprint: d.matrix.fingerprint(),
            hardware: hw,
            ordering: OrderingKind::Bmc,
            bs: 8,
            w: 4,
            spmv: crate::config::SpmvKind::Crs,
            sell_sigma: None,
            threads: 1,
            use_intrinsics: false,
            solve_seconds: 1e-3,
            setup_seconds: 1e-2,
            iterations: 10,
            baseline_solve_seconds: 2e-3,
            created_unix: 0,
        };
        assert!(!svc.install_profile(foreign).unwrap(), "cross-machine profiles must not install");
        assert_eq!(svc.stats().profiles, 0);
        let out = svc.solve(h, &d.b).unwrap();
        assert!(out.report.plan.config_label.starts_with("HBMC"));
        assert_eq!(svc.stats().profile_hits, 0);
    }

    #[test]
    fn empty_batch_is_free() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        let rhss: Vec<Vec<f64>> = Vec::new();
        let outs = svc.solve_many(h, &rhss).unwrap();
        assert!(outs.is_empty());
        let s = svc.stats();
        assert_eq!(s.builds, 0, "empty batch must not build a plan");
        assert_eq!(s.cache.misses, 0);
        assert_eq!(s.solves, 0);
        assert_eq!(s.batches, 0, "empty batch must not reach the queue");
    }
}
