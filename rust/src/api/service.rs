//! [`SolverService`] — the thread-safe serving façade.
//!
//! One service owns (a) a registry of matrices behind opaque
//! [`MatrixHandle`]s and (b) the LRU [`PlanCache`] behind an `RwLock`,
//! with a per-[`PlanKey`] build gate so that **concurrent requests for the
//! same (matrix, config) trigger exactly one plan build** — the others
//! wait on the gate and then take the cached plan. Solves themselves never
//! hold either lock: a request checks out an `Arc<SolverPlan>`, opens a
//! short-lived [`SolveSession`] with the *request's* pool width and
//! convergence controls, and runs.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering as AtomicOrdering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard};

use crate::config::SolverConfig;
use crate::coordinator::driver::SolveOptions;
use crate::coordinator::session::{CacheStats, PlanCache, PlanKey, SolveOutput, SolveSession};
use crate::error::{HbmcError, Result};
use crate::solver::plan::SolverPlan;
use crate::sparse::csr::Csr;

/// Opaque ticket for a matrix registered with a [`SolverService`]. Cheap to
/// copy and share across threads. Ids are allocated from one process-wide
/// counter, so a handle presented to a service other than its issuer can
/// never alias a different matrix — it fails with
/// [`HbmcError::UnknownMatrix`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MatrixHandle(u64);

impl MatrixHandle {
    /// The raw registry id (diagnostics, log correlation).
    pub fn id(&self) -> u64 {
        self.0
    }
}

/// Process-wide handle allocator (see [`MatrixHandle`]).
static NEXT_MATRIX_ID: AtomicU64 = AtomicU64::new(1);

/// A registry entry: the shared matrix plus its content fingerprint,
/// hashed once at registration (an O(nnz) scan) rather than per request.
#[derive(Clone)]
struct Registered {
    matrix: Arc<Csr>,
    fingerprint: u64,
}

/// Per-request overrides layered on the service's default configuration.
///
/// `config` swaps the *structural* configuration (ordering, bs, w, storage
/// — a different [`PlanKey`], hence possibly a different cached plan);
/// `options` carries the per-solve knobs (rtol/max_iters overrides,
/// history, solution copy) that never invalidate a plan.
#[derive(Debug, Clone, Default)]
pub struct SolveRequest {
    /// Structural config for this request; `None` = the service default.
    pub config: Option<SolverConfig>,
    /// Per-solve options (tolerance/iteration overrides, history, …).
    pub options: SolveOptions,
    /// Turn a non-converged result into [`HbmcError::NotConverged`]
    /// instead of an `Ok` report with `converged == false`.
    pub require_convergence: bool,
}

impl SolveRequest {
    pub fn new() -> SolveRequest {
        SolveRequest::default()
    }

    /// Use this structural config (a different plan-cache key) instead of
    /// the service default.
    pub fn with_config(mut self, cfg: SolverConfig) -> SolveRequest {
        self.config = Some(cfg);
        self
    }

    /// Override the convergence tolerance for this request only.
    pub fn rtol(mut self, rtol: f64) -> SolveRequest {
        self.options.rtol = Some(rtol);
        self
    }

    /// Override the iteration cap for this request only.
    pub fn max_iters(mut self, max_iters: usize) -> SolveRequest {
        self.options.max_iters = Some(max_iters);
        self
    }

    /// Record the per-iteration residual history.
    pub fn record_history(mut self) -> SolveRequest {
        self.options.record_history = true;
        self
    }

    /// Copy the solution vector into the report.
    pub fn return_solution(mut self) -> SolveRequest {
        self.options.return_solution = true;
        self
    }

    /// Fail with [`HbmcError::NotConverged`] when the cap is reached.
    pub fn require_convergence(mut self) -> SolveRequest {
        self.require_convergence = true;
        self
    }
}

/// Point-in-time service counters: registry size, plan-cache counters, and
/// the build/coalescing behaviour under concurrency.
#[derive(Debug, Clone, Copy)]
pub struct ServiceStats {
    /// Matrices currently registered.
    pub matrices: usize,
    /// Plan-cache snapshot (len/capacity/hits/misses/evictions).
    pub cache: CacheStats,
    /// Plans actually built by this service (== cache misses).
    pub builds: u64,
    /// Requests that waited on another thread's in-flight build instead of
    /// building themselves.
    pub coalesced_builds: u64,
    /// Solves completed through the service.
    pub solves: u64,
}

/// Thread-safe solve endpoint; see module docs. `Send + Sync` — share one
/// instance behind an `Arc` across all request threads.
pub struct SolverService {
    default_cfg: SolverConfig,
    matrices: RwLock<HashMap<u64, Registered>>,
    cache: RwLock<PlanCache>,
    /// Per-key build gates: the map lock is held only to look up/insert a
    /// gate; the gate itself is held for the duration of one plan build.
    building: Mutex<HashMap<PlanKey, Arc<Mutex<()>>>>,
    builds: AtomicU64,
    coalesced: AtomicU64,
    solves: AtomicU64,
}

/// Default plan-cache capacity (`SolverService::new`).
pub const DEFAULT_PLAN_CAPACITY: usize = 8;

// Lock helpers: the service never panics while holding a lock on the hot
// path, but a poisoned lock must not cascade — recover the guard.
fn wlock<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

fn rlock<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

fn mlock<T>(l: &Mutex<T>) -> MutexGuard<'_, T> {
    l.lock().unwrap_or_else(PoisonError::into_inner)
}

impl SolverService {
    /// Service with the default configuration and plan-cache capacity.
    pub fn new() -> SolverService {
        SolverService::with_capacity(SolverConfig::default(), DEFAULT_PLAN_CAPACITY)
            .expect("default config is valid")
    }

    /// Service whose `solve(handle, b)` uses `default_cfg`; fails fast on
    /// an invalid config rather than at first request.
    pub fn with_config(default_cfg: SolverConfig) -> Result<SolverService> {
        SolverService::with_capacity(default_cfg, DEFAULT_PLAN_CAPACITY)
    }

    /// Full constructor: default config + plan-cache capacity (≥ 1).
    pub fn with_capacity(default_cfg: SolverConfig, capacity: usize) -> Result<SolverService> {
        default_cfg.validate()?;
        if capacity == 0 {
            return Err(HbmcError::invalid_config("plan cache capacity must be >= 1"));
        }
        Ok(SolverService {
            default_cfg,
            matrices: RwLock::new(HashMap::new()),
            cache: RwLock::new(PlanCache::new(capacity)),
            building: Mutex::new(HashMap::new()),
            builds: AtomicU64::new(0),
            coalesced: AtomicU64::new(0),
            solves: AtomicU64::new(0),
        })
    }

    /// The configuration used when a request carries no override.
    pub fn default_config(&self) -> &SolverConfig {
        &self.default_cfg
    }

    /// Register a matrix; the returned handle addresses it in every later
    /// call. Registration never builds a plan — that happens lazily (and
    /// exactly once per distinct config) at first solve.
    pub fn register_matrix(&self, a: Csr) -> MatrixHandle {
        self.register_matrix_arc(Arc::new(a))
    }

    /// Zero-copy registration for callers that already share the matrix.
    /// The matrix is fingerprinted here, once, so later plan-cache lookups
    /// never rescan it.
    pub fn register_matrix_arc(&self, a: Arc<Csr>) -> MatrixHandle {
        let id = NEXT_MATRIX_ID.fetch_add(1, AtomicOrdering::SeqCst);
        let entry = Registered { fingerprint: a.fingerprint(), matrix: a };
        wlock(&self.matrices).insert(id, entry);
        MatrixHandle(id)
    }

    /// Drop a matrix from the registry. Cached plans for it age out of the
    /// LRU naturally; in-flight solves holding the plan are unaffected.
    pub fn unregister_matrix(&self, handle: MatrixHandle) -> Result<()> {
        match wlock(&self.matrices).remove(&handle.0) {
            Some(_) => Ok(()),
            None => Err(HbmcError::UnknownMatrix(format!("handle #{}", handle.0))),
        }
    }

    fn registered(&self, handle: MatrixHandle) -> Result<Registered> {
        rlock(&self.matrices)
            .get(&handle.0)
            .cloned()
            .ok_or_else(|| HbmcError::UnknownMatrix(format!("handle #{}", handle.0)))
    }

    /// The registered matrix behind `handle`.
    pub fn matrix(&self, handle: MatrixHandle) -> Result<Arc<Csr>> {
        Ok(self.registered(handle)?.matrix)
    }

    /// Get-or-build the plan for `(handle, cfg)` with single-build
    /// coalescing (the tentpole guarantee: concurrent same-key requests
    /// produce exactly one `SolverPlan::build`).
    pub fn plan(&self, handle: MatrixHandle, cfg: &SolverConfig) -> Result<Arc<SolverPlan>> {
        cfg.validate()?;
        let reg = self.registered(handle)?;
        self.plan_for(&reg, cfg)
    }

    fn plan_for(&self, reg: &Registered, cfg: &SolverConfig) -> Result<Arc<SolverPlan>> {
        let key = PlanKey::from_fingerprint(reg.fingerprint, cfg);
        // Fast path: cached (write lock — `get` touches the LRU clock).
        if let Some(plan) = wlock(&self.cache).get(&key) {
            return Ok(plan);
        }
        // Slow path: take this key's build gate so one thread builds while
        // the rest wait here, not in a duplicate factorization.
        let gate = mlock(&self.building).entry(key.clone()).or_default().clone();
        let permit = mlock(&gate);
        // Re-check under the gate: whoever held it before us has inserted.
        if let Some(plan) = wlock(&self.cache).get(&key) {
            self.coalesced.fetch_add(1, AtomicOrdering::SeqCst);
            drop(permit);
            self.release_gate(&key, &gate);
            return Ok(plan);
        }
        let result = SolverPlan::build(&reg.matrix, cfg).map(|plan| {
            let plan = Arc::new(plan);
            self.builds.fetch_add(1, AtomicOrdering::SeqCst);
            wlock(&self.cache).insert(key.clone(), plan.clone());
            plan
        });
        drop(permit);
        self.release_gate(&key, &gate);
        result
    }

    /// Retire a build gate once no other thread is waiting on it. Removing
    /// only when we hold the map's sole outside reference keeps the gate
    /// stable while contended — every concurrent requester for a key always
    /// serializes on the *same* mutex, so a rebuilt (failed or evicted) key
    /// can never be built twice at once — while still letting idle entries
    /// be reclaimed instead of accumulating per distinct key.
    fn release_gate(&self, key: &PlanKey, gate: &Arc<Mutex<()>>) {
        let mut map = mlock(&self.building);
        // Strong refs on the entry: the map's + ours (`gate`) + one per
        // thread that has fetched it and not yet released. <= 2 means
        // nobody else can be waiting; a later requester must go through
        // the map lock we hold, so the count cannot grow under us.
        let retire = map
            .get(key)
            .is_some_and(|current| Arc::ptr_eq(current, gate) && Arc::strong_count(current) <= 2);
        if retire {
            map.remove(key);
        }
    }

    /// Open a [`SolveSession`] on the (cached or freshly built) plan for
    /// `(handle, cfg)`, with the request's pool width and tolerances. For
    /// callers that want to hold one session across a burst of solves.
    pub fn session(&self, handle: MatrixHandle, cfg: &SolverConfig) -> Result<SolveSession> {
        let plan = self.plan(handle, cfg)?;
        Ok(SolveSession::for_request(plan, cfg))
    }

    /// Solve `A x = b` under the service's default configuration.
    ///
    /// Each call opens a short-lived session, which spawns a pool of
    /// `threads - 1` workers; with the default `threads = 1` that is free.
    /// Callers sustaining a high request rate on a multi-threaded config
    /// should hold a [`session`](SolverService::session) (one persistent
    /// pool) or batch with [`solve_many`](SolverService::solve_many).
    pub fn solve(&self, handle: MatrixHandle, b: &[f64]) -> Result<SolveOutput> {
        self.solve_with(handle, b, &SolveRequest::default())
    }

    /// Solve with per-request overrides (see [`solve`](SolverService::solve)
    /// for the per-call pool note).
    pub fn solve_with(
        &self,
        handle: MatrixHandle,
        b: &[f64],
        req: &SolveRequest,
    ) -> Result<SolveOutput> {
        let outs = self.solve_many_with(handle, &[b], req)?;
        Ok(outs.into_iter().next().expect("one rhs in, one output out"))
    }

    /// Batched serving: all right-hand sides run on one session (one pool,
    /// one plan checkout). Results are index-aligned with `rhss`.
    pub fn solve_many<B: AsRef<[f64]>>(
        &self,
        handle: MatrixHandle,
        rhss: &[B],
    ) -> Result<Vec<SolveOutput>> {
        self.solve_many_with(handle, rhss, &SolveRequest::default())
    }

    /// Batched serving with per-request overrides (applied to every rhs).
    ///
    /// Dimension checks run up front, so a malformed batch is rejected
    /// before any solve. With
    /// [`require_convergence`](SolveRequest::require_convergence), the
    /// batch fails fast on the first rhs that stalls: completed outputs are
    /// discarded and later rhss do not run — solve rhss individually when
    /// partial results of a batch that may stall matter.
    pub fn solve_many_with<B: AsRef<[f64]>>(
        &self,
        handle: MatrixHandle,
        rhss: &[B],
        req: &SolveRequest,
    ) -> Result<Vec<SolveOutput>> {
        let reg = self.registered(handle)?;
        let n = reg.matrix.n();
        let cfg = req.config.as_ref().unwrap_or(&self.default_cfg);
        cfg.validate()?;
        // Reject every malformed rhs up front — a batch must not run
        // halfway before tripping on rhs k.
        for b in rhss {
            let got = b.as_ref().len();
            if got != n {
                return Err(HbmcError::DimensionMismatch { expected: n, got });
            }
        }
        let plan = self.plan_for(&reg, cfg)?;
        let session = SolveSession::for_request(plan, cfg);
        let mut outs = Vec::with_capacity(rhss.len());
        for b in rhss {
            let out = session.solve_with(b.as_ref(), &req.options)?;
            self.solves.fetch_add(1, AtomicOrdering::SeqCst);
            if req.require_convergence && !out.report.converged {
                return Err(HbmcError::NotConverged {
                    iterations: out.report.iterations,
                    relres: out.report.final_relres,
                });
            }
            outs.push(out);
        }
        Ok(outs)
    }

    /// Counters: registry size, cache hits/misses/evictions, coalesced
    /// builds, solves served.
    pub fn stats(&self) -> ServiceStats {
        ServiceStats {
            matrices: rlock(&self.matrices).len(),
            cache: rlock(&self.cache).stats(),
            builds: self.builds.load(AtomicOrdering::SeqCst),
            coalesced_builds: self.coalesced.load(AtomicOrdering::SeqCst),
            solves: self.solves.load(AtomicOrdering::SeqCst),
        }
    }
}

impl Default for SolverService {
    fn default() -> Self {
        SolverService::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{OrderingKind, Scale};
    use crate::gen::suite;

    fn tiny_cfg(ordering: OrderingKind) -> SolverConfig {
        SolverConfig { ordering, bs: 8, w: 4, rtol: 1e-7, ..Default::default() }
    }

    #[test]
    fn service_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<SolverService>();
        assert_send_sync::<MatrixHandle>();
    }

    #[test]
    fn register_solve_and_stats() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        let o1 = svc.solve(h, &d.b).unwrap();
        let o2 = svc.solve(h, &d.b).unwrap();
        assert!(o1.report.converged);
        assert_eq!(o1.x, o2.x, "same plan + rhs must be deterministic");
        let s = svc.stats();
        assert_eq!(s.matrices, 1);
        assert_eq!(s.builds, 1, "second solve must reuse the cached plan");
        assert_eq!(s.cache.hits, 1);
        assert_eq!(s.solves, 2);
    }

    #[test]
    fn unknown_handle_is_typed() {
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Bmc)).unwrap();
        let d = suite::dataset("thermal2", Scale::Tiny);
        let h = svc.register_matrix(d.matrix.clone());
        svc.unregister_matrix(h).unwrap();
        let err = svc.solve(h, &d.b).unwrap_err();
        assert!(matches!(err, HbmcError::UnknownMatrix(_)), "{err:?}");
        assert!(matches!(svc.unregister_matrix(h), Err(HbmcError::UnknownMatrix(_))));
    }

    #[test]
    fn dimension_mismatch_is_typed_for_solve_and_batch() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        let n = d.matrix.n();
        let err = svc.solve(h, &[1.0, 2.0]).unwrap_err();
        assert!(
            matches!(err, HbmcError::DimensionMismatch { expected, got }
                if expected == n && got == 2),
            "{err:?}"
        );
        // A batch with one bad rhs is rejected before any solve runs.
        let err = svc.solve_many(h, &[d.b.clone(), vec![0.0; 3]]).unwrap_err();
        assert!(matches!(err, HbmcError::DimensionMismatch { got: 3, .. }), "{err:?}");
        assert_eq!(svc.stats().solves, 0, "rejected batch must not run");
    }

    #[test]
    fn per_request_config_overrides_use_distinct_plans() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        svc.solve(h, &d.b).unwrap();
        let req = SolveRequest::new().with_config(tiny_cfg(OrderingKind::Bmc));
        svc.solve_with(h, &d.b, &req).unwrap();
        assert_eq!(svc.stats().builds, 2, "different ordering = different plan key");
        // rtol/max_iters overrides do NOT make a new plan.
        svc.solve_with(h, &d.b, &SolveRequest::new().rtol(1e-3)).unwrap();
        assert_eq!(svc.stats().builds, 2);
    }

    #[test]
    fn require_convergence_yields_not_converged() {
        let d = suite::dataset("g3_circuit", Scale::Tiny);
        let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
        let h = svc.register_matrix(d.matrix.clone());
        let req = SolveRequest::new().max_iters(2).require_convergence();
        let err = svc.solve_with(h, &d.b, &req).unwrap_err();
        assert!(
            matches!(err, HbmcError::NotConverged { iterations: 2, .. }),
            "{err:?}"
        );
        // Without the flag the same request is an Ok non-converged report.
        let out = svc.solve_with(h, &d.b, &SolveRequest::new().max_iters(2)).unwrap();
        assert!(!out.report.converged);
    }
}
