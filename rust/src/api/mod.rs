//! The typed, concurrent public API — the one front door production
//! callers go through:
//!
//! * [`HbmcError`](crate::error::HbmcError) — the typed error every public
//!   library function returns (re-exported here for convenience),
//! * [`SolverConfigBuilder`](crate::config::SolverConfigBuilder) — the
//!   validating config constructor ([`SolverConfig::builder`]),
//! * [`SolverService`] — a `Send + Sync` solve endpoint that owns the
//!   matrix registry and the plan cache, coalesces concurrent plan builds
//!   per [`PlanKey`](crate::coordinator::session::PlanKey), and serves
//!   `solve` / `solve_many` with per-request [`SolveRequest`] overrides.
//!
//! The lower layers (plans, sessions, kernels) remain public for research
//! scripts and the reproduction benches; the service is the shape the
//! ROADMAP's serving story ("a few matrices, many right-hand sides, many
//! concurrent callers") is built on.
//!
//! [`SolverConfig::builder`]: crate::config::SolverConfig::builder

mod service;

pub use crate::config::{SolverConfig, SolverConfigBuilder};
pub use crate::error::{HbmcError, Result};
pub use service::{MatrixHandle, ServiceStats, SolveRequest, SolverService};
