//! The typed, concurrent public API — the one front door production
//! callers go through:
//!
//! * [`HbmcError`](crate::error::HbmcError) — the typed error every public
//!   library function returns (re-exported here for convenience),
//! * [`SolverConfigBuilder`](crate::config::SolverConfigBuilder) — the
//!   validating config constructor ([`SolverConfig::builder`]),
//! * [`SolverService`] — a `Send + Sync` solve endpoint that owns the
//!   matrix registry, the plan cache (coalescing concurrent plan builds
//!   per [`PlanKey`](crate::coordinator::session::PlanKey)), and an
//!   asynchronous job queue: [`submit`](SolverService::submit) returns a
//!   [`JobHandle`] (poll / wait / cancel, per-job deadlines), and a
//!   dispatcher thread micro-batches compatible jobs onto shared sessions
//!   so concurrent single-RHS traffic shares plan checkouts and warmed-up
//!   pools instead of paying per-request setup.
//!   The blocking `solve` / `solve_many` calls are submit + wait wrappers
//!   over the same queue.
//!
//! The service is also the front door of the autotuner (see
//! [`crate::tune`]): [`SolverService::tune`] searches the configuration
//! space for a registered matrix on this machine and installs/persists
//! the winning [`TunedProfile`], which later default-config requests
//! auto-apply (opt out per request via
//! [`SolveRequest::no_profile`](SolveRequest::no_profile); observe via
//! `ServiceStats::profile_hits`).
//!
//! The service is observable and load-shedding (see [`crate::obs`] and
//! ARCHITECTURE.md "Observability & admission control"):
//! [`SolverService::metrics_text`] renders every counter and histogram in
//! Prometheus text exposition format (served over HTTP by
//! `hbmc serve --metrics-addr`), [`SolverService::trace_json`] dumps the
//! sampled job-lifecycle trace, and [`QueueConfig`] bounds — queue depth
//! and per-handle in-flight quota — turn floods into fast, typed
//! [`HbmcError::Overloaded`](crate::error::HbmcError::Overloaded)
//! rejections instead of unbounded memory growth.
//!
//! The lower layers (plans, sessions, kernels) remain public for research
//! scripts and the reproduction benches; the service is the shape the
//! ROADMAP's serving story ("a few matrices, many right-hand sides, many
//! concurrent callers") is built on.
//!
//! [`SolverConfig::builder`]: crate::config::SolverConfig::builder

mod job;
mod queue;
mod service;

pub use crate::config::{QueueConfig, SolverConfig, SolverConfigBuilder};
pub use crate::error::{HbmcError, Result};
pub use crate::obs::{HistogramSnapshot, MetricsSnapshot, TraceEvent};
pub use crate::tune::{HardwareSignature, ProfileStore, TuneOptions, TunedProfile};
pub use job::{JobHandle, JobState};
pub use service::{MatrixHandle, ServiceStats, SolveRequest, SolverService};
