//! Coarsening: merge runs of thin wavefronts into serial segments.
//!
//! Wavefronts of irregular matrices have long thin tails — levels with a
//! handful of rows, where a barrier costs far more than the work it
//! separates. This pass classifies each level as *thin* or *fat* under
//! [`CoarsenParams`], merges maximal runs of equal thin-ness into
//! [`Segment`]s, and executes thin runs serially on one thread (no
//! barriers inside the run) while fat runs keep barrier-per-level
//! parallel execution.
//!
//! A merged thin run may interleave rows of different levels, but serial
//! ascending-index execution is always topologically valid: every forward
//! dependency points to a strictly smaller row index (strict lower
//! factor), so the rows of a segment are sorted ascending and walked in
//! order (descending for the backward sweep, whose dependencies point the
//! other way). The thin/fat thresholds are deliberately independent of
//! the thread count, so the coarsened stage count — and with it the
//! solver's `num_colors` and the whole sync model — is a pure function of
//! the factor's pattern.

use crate::factor::split::TriFactors;
use crate::schedule::levels::LevelSchedule;

/// How a segment executes inside the substitution sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SegmentMode {
    /// Parallel level-by-level, with a barrier between consecutive levels.
    Barrier,
    /// All rows of the segment run serially on thread 0, no internal syncs.
    Serial,
}

/// A maximal run of levels `level_lo..level_hi` sharing one execution mode.
#[derive(Debug, Clone, Copy)]
pub struct Segment {
    pub level_lo: usize,
    pub level_hi: usize,
    pub mode: SegmentMode,
}

/// Thin-level thresholds. A level is *thin* when it has fewer than
/// `min_rows` rows **or** fewer than `min_nnz` factor nonzeros (both
/// triangles): either way there is not enough work to amortize a barrier.
/// Thread-count independent by design (see module docs).
#[derive(Debug, Clone, Copy)]
pub struct CoarsenParams {
    pub min_rows: usize,
    pub min_nnz: usize,
}

impl Default for CoarsenParams {
    fn default() -> Self {
        CoarsenParams { min_rows: 64, min_nnz: 512 }
    }
}

/// The executable schedule: the (possibly re-sorted) row order, level
/// boundaries, segments, and per-position weight prefixes for
/// nnz-balanced splitting inside parallel levels.
#[derive(Debug, Clone)]
pub struct CoarsenedSchedule {
    /// Row indices in execution order; within a parallel level ascending,
    /// within a serial segment ascending across its whole level range.
    pub rows: Vec<u32>,
    /// Level boundaries into `rows` (unchanged from [`LevelSchedule`]).
    pub level_ptr: Vec<usize>,
    /// Maximal mode-homogeneous level runs, ascending, covering all levels.
    pub segments: Vec<Segment>,
    /// `fwd_prefix[p + 1] - fwd_prefix[p]` = forward work of `rows[p]`
    /// (strict-lower nnz + 1); strictly increasing, for
    /// [`split_point`](crate::schedule::levels::split_point).
    pub fwd_prefix: Vec<u64>,
    /// Same with strict-upper nnz for the backward sweep.
    pub bwd_prefix: Vec<u64>,
}

impl CoarsenedSchedule {
    /// Barrier-separated stages per sweep: one per level of a `Barrier`
    /// segment, one per `Serial` segment. This is the level path's
    /// `num_colors` — `stages() - 1` barriers per substitution sweep.
    pub fn stages(&self) -> usize {
        self.segments
            .iter()
            .map(|s| match s.mode {
                SegmentMode::Barrier => s.level_hi - s.level_lo,
                SegmentMode::Serial => 1,
            })
            .sum::<usize>()
            .max(1)
    }

    pub fn num_levels(&self) -> usize {
        self.level_ptr.len() - 1
    }
}

/// Coarsen `levels` for `tri` under `params` (see module docs).
pub fn coarsen(
    levels: &LevelSchedule,
    tri: &TriFactors,
    params: &CoarsenParams,
) -> CoarsenedSchedule {
    let nlv = levels.num_levels();
    let lp = tri.lower.row_ptr();
    let up = tri.upper.row_ptr();
    let row_nnz = |p: &[u32], i: usize| (p[i + 1] - p[i]) as u64;

    // Classify each level; empty schedules (n = 0) yield no segments.
    let thin: Vec<bool> = (0..nlv)
        .map(|l| {
            let rows = levels.level(l);
            let nnz: u64 = rows
                .iter()
                .map(|&i| row_nnz(lp, i as usize) + row_nnz(up, i as usize))
                .sum();
            rows.len() < params.min_rows || (nnz as usize) < params.min_nnz
        })
        .collect();

    // Greedy maximal runs of equal thin-ness.
    let mut segments = Vec::new();
    let mut lo = 0;
    while lo < nlv {
        let mut hi = lo + 1;
        while hi < nlv && thin[hi] == thin[lo] {
            hi += 1;
        }
        let mode = if thin[lo] { SegmentMode::Serial } else { SegmentMode::Barrier };
        segments.push(Segment { level_lo: lo, level_hi: hi, mode });
        lo = hi;
    }

    // Serial segments execute ascending by row index across their whole
    // level range (valid: all deps point to smaller indices).
    let mut rows = levels.rows.clone();
    let level_ptr = levels.level_ptr.clone();
    for seg in &segments {
        if seg.mode == SegmentMode::Serial {
            rows[level_ptr[seg.level_lo]..level_ptr[seg.level_hi]].sort_unstable();
        }
    }

    // Weight prefixes over the final row order (+1 per row keeps them
    // strictly increasing so split windows stay monotone).
    let mut fwd_prefix = Vec::with_capacity(rows.len() + 1);
    let mut bwd_prefix = Vec::with_capacity(rows.len() + 1);
    fwd_prefix.push(0u64);
    bwd_prefix.push(0u64);
    for &i in &rows {
        let i = i as usize;
        fwd_prefix.push(fwd_prefix.last().unwrap() + row_nnz(lp, i) + 1);
        bwd_prefix.push(bwd_prefix.last().unwrap() + row_nnz(up, i) + 1);
    }

    CoarsenedSchedule { rows, level_ptr, segments, fwd_prefix, bwd_prefix }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::ic0::ic0;
    use crate::sparse::coo::Coo;
    use crate::sparse::csr::Csr;

    fn grid(nx: usize, ny: usize) -> Csr {
        let idx = |x: usize, y: usize| y * nx + x;
        let mut c = Coo::new(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                c.push(idx(x, y), idx(x, y), 4.0);
                if x + 1 < nx {
                    c.push_sym(idx(x, y), idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    c.push_sym(idx(x, y), idx(x, y + 1), -1.0);
                }
            }
        }
        c.to_csr()
    }

    fn factors(a: &Csr) -> TriFactors {
        TriFactors::from_ic(&ic0(a, 0.0).unwrap())
    }

    #[test]
    fn all_thin_levels_collapse_to_one_serial_stage() {
        // Small grid: every wavefront is far below the default thresholds,
        // so the whole sweep coarsens to one serial segment — zero syncs.
        let tri = factors(&grid(7, 5));
        let lv = LevelSchedule::build(&tri);
        assert!(lv.num_levels() > 1);
        let sched = coarsen(&lv, &tri, &CoarsenParams::default());
        assert_eq!(sched.segments.len(), 1);
        assert_eq!(sched.segments[0].mode, SegmentMode::Serial);
        assert_eq!(sched.stages(), 1);
        // Serial rows sorted ascending across the whole range.
        assert!(sched.rows.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn zero_thresholds_keep_every_level() {
        let tri = factors(&grid(7, 5));
        let lv = LevelSchedule::build(&tri);
        let sched = coarsen(&lv, &tri, &CoarsenParams { min_rows: 0, min_nnz: 0 });
        assert_eq!(sched.segments.len(), 1);
        assert_eq!(sched.segments[0].mode, SegmentMode::Barrier);
        assert_eq!(sched.stages(), lv.num_levels());
        assert_eq!(sched.rows, lv.rows);
    }

    #[test]
    fn mixed_thresholds_split_into_alternating_segments() {
        // On a 2-D grid wavefronts grow then shrink (anti-diagonals):
        // a middling min_rows makes thin–fat–thin runs.
        let tri = factors(&grid(24, 24));
        let lv = LevelSchedule::build(&tri);
        let sched = coarsen(&lv, &tri, &CoarsenParams { min_rows: 10, min_nnz: 0 });
        assert!(sched.segments.len() >= 2, "expected thin tails around a fat middle");
        // Segments tile the level range and alternate modes.
        assert_eq!(sched.segments[0].level_lo, 0);
        assert_eq!(sched.segments.last().unwrap().level_hi, lv.num_levels());
        for w in sched.segments.windows(2) {
            assert_eq!(w[0].level_hi, w[1].level_lo);
            assert_ne!(w[0].mode, w[1].mode, "adjacent segments must differ (maximal runs)");
        }
        // Stage count: fat levels count singly, serial runs count once.
        let by_hand: usize = sched
            .segments
            .iter()
            .map(|s| match s.mode {
                SegmentMode::Barrier => s.level_hi - s.level_lo,
                SegmentMode::Serial => 1,
            })
            .sum();
        assert_eq!(sched.stages(), by_hand);
        assert!(sched.stages() < lv.num_levels());
    }

    #[test]
    fn prefixes_are_strictly_increasing_and_count_nnz() {
        let tri = factors(&grid(9, 9));
        let lv = LevelSchedule::build(&tri);
        let sched = coarsen(&lv, &tri, &CoarsenParams::default());
        let n = sched.rows.len();
        assert_eq!(sched.fwd_prefix.len(), n + 1);
        assert_eq!(sched.bwd_prefix.len(), n + 1);
        assert!(sched.fwd_prefix.windows(2).all(|w| w[0] < w[1]));
        assert!(sched.bwd_prefix.windows(2).all(|w| w[0] < w[1]));
        // Totals = nnz + n for each triangle.
        assert_eq!(*sched.fwd_prefix.last().unwrap(), (tri.lower.nnz() + n) as u64);
        assert_eq!(*sched.bwd_prefix.last().unwrap(), (tri.upper.nnz() + n) as u64);
    }

    #[test]
    fn coarsening_preserves_the_row_set() {
        let tri = factors(&grid(13, 11));
        let lv = LevelSchedule::build(&tri);
        let sched = coarsen(&lv, &tri, &CoarsenParams { min_rows: 6, min_nnz: 0 });
        let mut a = sched.rows.clone();
        let mut b = lv.rows.clone();
        a.sort_unstable();
        b.sort_unstable();
        assert_eq!(a, b);
        assert_eq!(sched.level_ptr, lv.level_ptr);
    }
}
