//! Level scheduling for the sparse triangular solver: the *scheduling*
//! alternative to the reordering family (MC / BMC / HBMC).
//!
//! The paper's orderings buy parallel substitution sweeps by permuting the
//! matrix, which perturbs the IC(0) preconditioner and inflates ICCG
//! iteration counts (§5.3). Level scheduling (Böhnlein et al.; Li's CUDA
//! level-sets — see PAPERS.md) keeps the **natural ordering** — and hence
//! the serial solver's convergence, bit for bit — and instead extracts the
//! parallelism already present in the factor's dependency DAG:
//!
//! * [`levels`] — wavefront construction: in-degree peeling of the strict
//!   lower factor partitions the rows into *level sets*; rows of one level
//!   are mutually independent, so one level is one parallel loop, exactly
//!   like one color of the MC sweep. The same partition, walked in
//!   descending order, schedules the backward (`Lᵀ`) sweep.
//! * [`coarsen`] — the cost-model pass: wavefronts of irregular matrices
//!   have long thin tails (a handful of rows per level) where a barrier
//!   costs more than the rows it separates. Runs of thin levels are merged
//!   into serial segments, trading worthless parallelism for barriers.
//! * [`cost`] — the analytic model behind the coarsening decision
//!   (barrier-per-level vs per-row ready-flag spinning), surfaced through
//!   `PlanReport::schedule` so tuning and reports can see *why* a schedule
//!   has the stage count it has.
//!
//! The executor lives in `solver::trisolve_level` (fifth `TriSolver`
//! path, `OrderingKind::Level`); the autotuner races it against the
//! reordering paths per (matrix, hardware).

pub mod coarsen;
pub mod cost;
pub mod levels;
