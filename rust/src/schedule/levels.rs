//! Level sets (wavefronts) of the IC(0) factor's strict-lower dependency
//! DAG, computed by in-degree peeling.
//!
//! Row `i` of the forward substitution depends on row `j` exactly when
//! `l_ij ≠ 0` (`j < i`); level `0` is the set of rows with an empty strict
//! lower row, level `k + 1` the rows whose last unfinished dependency sits
//! in level `k`. Rows of one level are mutually independent — in **either**
//! sweep direction, since every edge of the DAG crosses levels — so the
//! forward sweep walks levels ascending and the backward (`Lᵀ`) sweep walks
//! the *same* levels descending, mirroring how the MC solver walks its
//! `color_ptr` both ways.
//!
//! Construction is deterministic and thread-count-independent: rows within
//! a level are kept in ascending index order, so the schedule (and with it
//! `num_colors`, the sync model, and every report) is a pure function of
//! the factor's pattern.

use crate::factor::split::TriFactors;

/// The wavefront partition: rows grouped by level, ascending within each.
#[derive(Debug, Clone)]
pub struct LevelSchedule {
    /// Row indices grouped by level; rows of level `l` are
    /// `rows[level_ptr[l]..level_ptr[l + 1]]`, ascending.
    pub rows: Vec<u32>,
    /// Level boundaries into `rows` (`level_ptr.len() == num_levels + 1`).
    pub level_ptr: Vec<usize>,
}

impl LevelSchedule {
    /// Peel the strict-lower DAG of `tri`: in-degree of row `i` is its
    /// strict-lower nonzero count; finishing row `j` decrements every
    /// dependent, which `tri.upper` (strict upper of `Lᵀ`) lists directly
    /// — row `j` of `upper` holds exactly the `i > j` with `l_ij ≠ 0`.
    pub fn build(tri: &TriFactors) -> LevelSchedule {
        let n = tri.n();
        let lp = tri.lower.row_ptr();
        let up = tri.upper.row_ptr();
        let ucols = tri.upper.cols();
        let mut indeg: Vec<u32> = lp.windows(2).map(|w| w[1] - w[0]).collect();
        let mut frontier: Vec<u32> =
            (0..n).filter(|&i| indeg[i] == 0).map(|i| i as u32).collect();
        let mut rows = Vec::with_capacity(n);
        let mut level_ptr = vec![0usize];
        while !frontier.is_empty() {
            rows.extend_from_slice(&frontier);
            level_ptr.push(rows.len());
            let mut next = Vec::new();
            for &j in &frontier {
                let j = j as usize;
                for k in up[j] as usize..up[j + 1] as usize {
                    let i = ucols[k] as usize;
                    indeg[i] -= 1;
                    if indeg[i] == 0 {
                        next.push(i as u32);
                    }
                }
            }
            // Dependents are discovered in finish order; re-sort so rows
            // within a level are ascending (determinism + locality).
            next.sort_unstable();
            frontier = next;
        }
        assert_eq!(rows.len(), n, "triangular DAG must peel completely");
        LevelSchedule { rows, level_ptr }
    }

    pub fn num_levels(&self) -> usize {
        self.level_ptr.len() - 1
    }

    pub fn n(&self) -> usize {
        self.rows.len()
    }

    /// Rows of level `l` (ascending).
    pub fn level(&self, l: usize) -> &[u32] {
        &self.rows[self.level_ptr[l]..self.level_ptr[l + 1]]
    }
}

/// Deterministic nnz-balanced split of the position window `lo..hi` for
/// thread `t` of `nt` — the `RowSplits::balanced` idiom from
/// `solver::spmv` applied to a per-position weight prefix instead of a CSR
/// `row_ptr`. `prefix` must be strictly increasing over `lo..=hi` (the
/// schedule's `+1`-per-row weights guarantee it), which makes the splits
/// monotone, disjoint and covering: `t = 0 ↦ lo`, `t = nt ↦ hi`.
///
/// The assignment is fixed per `(t, nt)`; bitwise invariance **across**
/// thread counts needs no alignment tricks here because a substitution
/// sweep has no reductions — every `y[i]` is produced by exactly one row.
pub fn split_point(prefix: &[u64], lo: usize, hi: usize, t: usize, nt: usize) -> usize {
    let total = prefix[hi] - prefix[lo];
    let target = prefix[lo] + total * t as u64 / nt as u64;
    lo + prefix[lo..=hi].partition_point(|&p| p < target)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::ic0::ic0;
    use crate::sparse::coo::Coo;
    use crate::sparse::csr::Csr;

    fn grid(nx: usize, ny: usize) -> Csr {
        let idx = |x: usize, y: usize| y * nx + x;
        let mut c = Coo::new(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                c.push(idx(x, y), idx(x, y), 4.0);
                if x + 1 < nx {
                    c.push_sym(idx(x, y), idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    c.push_sym(idx(x, y), idx(x, y + 1), -1.0);
                }
            }
        }
        c.to_csr()
    }

    fn factors(a: &Csr) -> TriFactors {
        TriFactors::from_ic(&ic0(a, 0.0).unwrap())
    }

    #[test]
    fn levels_partition_all_rows_and_respect_dependencies() {
        let tri = factors(&grid(9, 7));
        let lv = LevelSchedule::build(&tri);
        assert_eq!(lv.n(), 63);
        assert!(lv.num_levels() >= 2);
        // Every row appears exactly once.
        let mut seen = vec![false; 63];
        for &i in &lv.rows {
            assert!(!seen[i as usize], "row {i} scheduled twice");
            seen[i as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
        // Level of each row, for the dependency check.
        let mut level_of = vec![usize::MAX; 63];
        for l in 0..lv.num_levels() {
            for &i in lv.level(l) {
                level_of[i as usize] = l;
            }
        }
        // Every strict-lower dependency sits in a strictly earlier level.
        let (rp, cols) = (tri.lower.row_ptr(), tri.lower.cols());
        for i in 0..63 {
            for k in rp[i] as usize..rp[i + 1] as usize {
                let j = cols[k] as usize;
                assert!(
                    level_of[j] < level_of[i],
                    "dep {j} (level {}) not before {i} (level {})",
                    level_of[j],
                    level_of[i]
                );
            }
        }
        // Rows within a level are ascending (deterministic construction).
        for l in 0..lv.num_levels() {
            let rows = lv.level(l);
            assert!(rows.windows(2).all(|w| w[0] < w[1]), "level {l} not sorted");
        }
    }

    #[test]
    fn diagonal_matrix_is_one_level() {
        let n = 10;
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, 2.0);
        }
        let tri = factors(&c.to_csr());
        let lv = LevelSchedule::build(&tri);
        assert_eq!(lv.num_levels(), 1);
        assert_eq!(lv.level(0).len(), n);
    }

    #[test]
    fn tridiagonal_matrix_is_fully_sequential() {
        let n = 12;
        let mut c = Coo::new(n);
        for i in 0..n {
            c.push(i, i, 4.0);
        }
        for i in 0..n - 1 {
            c.push_sym(i, i + 1, -1.0);
        }
        let tri = factors(&c.to_csr());
        let lv = LevelSchedule::build(&tri);
        // A chain: every row waits for its predecessor — n levels of 1.
        assert_eq!(lv.num_levels(), n);
        for l in 0..n {
            assert_eq!(lv.level(l), &[l as u32]);
        }
    }

    #[test]
    fn split_points_are_monotone_disjoint_covering() {
        // Strictly increasing prefix with uneven weights.
        let weights = [5u64, 1, 1, 9, 2, 2, 2, 40, 1, 1];
        let mut prefix = vec![0u64];
        for w in weights {
            prefix.push(prefix.last().unwrap() + w + 1);
        }
        let (lo, hi) = (0usize, weights.len());
        for nt in 1..=6 {
            let mut prev = lo;
            assert_eq!(split_point(&prefix, lo, hi, 0, nt), lo);
            for t in 1..=nt {
                let p = split_point(&prefix, lo, hi, t, nt);
                assert!(p >= prev, "nt={nt} t={t}: {p} < {prev}");
                prev = p;
            }
            assert_eq!(prev, hi, "nt={nt}: splits must cover the window");
        }
        // A sub-window behaves the same.
        assert_eq!(split_point(&prefix, 3, 7, 0, 2), 3);
        assert_eq!(split_point(&prefix, 3, 7, 2, 2), 7);
    }
}
