//! The analytic cost model behind the coarsening decision, surfaced
//! through `PlanReport::schedule`.
//!
//! Three sweep-execution strategies are compared in abstract work units
//! (1 unit ≈ one factor nonzero processed):
//!
//! * **barrier-per-level** — the uncoarsened schedule: perfect parallelism
//!   inside each wavefront, one [`BARRIER_COST`] per level boundary.
//! * **coarsened** — thin-level runs merged serially
//!   ([`coarsen`](crate::schedule::coarsen)): fewer barriers, a little
//!   serialized work.
//! * **per-row ready-flag spinning** — the barrier-free alternative where
//!   each row spins on its dependencies' done-flags ([`SPIN_COST`] per
//!   dependency check). Modeled only: flags would need per-solve mutable
//!   state inside the otherwise immutable `Arc`-shared plan, and the model
//!   shows coarsened barriers winning at the suite's scales anyway.
//!
//! The struct is cloned into every report, so per-level detail is
//! compressed to a log₂ histogram rather than full vectors.

use crate::factor::split::TriFactors;
use crate::schedule::coarsen::{CoarsenedSchedule, SegmentMode};
use crate::schedule::levels::LevelSchedule;

/// Model cost of one pool barrier, in per-nonzero work units. Chosen for
/// a ~100 ns barrier against ~0.25 ns per nonzero on the fused-loop
/// hardware class; only ratios matter to the comparison.
pub const BARRIER_COST: f64 = 400.0;

/// Model cost of one ready-flag dependency check, in the same units — a
/// cross-core cache-line probe per strict-lower nonzero.
pub const SPIN_COST: f64 = 8.0;

/// Shape and predicted cost of a level schedule (one sweep direction;
/// forward and backward are symmetric in this model).
#[derive(Debug, Clone)]
pub struct ScheduleCost {
    /// Wavefront count before coarsening.
    pub levels: usize,
    /// log₂-bucketed histogram of rows per level: `rows_per_level[b]`
    /// counts levels with `rows ∈ [2ᵇ, 2ᵇ⁺¹)`.
    pub rows_per_level: Vec<usize>,
    pub max_level_rows: usize,
    /// Factor nonzeros over both triangles.
    pub total_nnz: usize,
    pub mean_level_nnz: f64,
    pub max_level_nnz: usize,
    /// Barrier-separated stages after coarsening (the path's `num_colors`).
    pub coarsened_stages: usize,
    pub serial_segments: usize,
    /// Rows executed serially on thread 0.
    pub serialized_rows: usize,
    /// `coarsened_stages - 1` — what the executor actually does per sweep.
    pub predicted_syncs_per_sweep: usize,
    /// Modeled sweep costs in work units (see module docs).
    pub barrier_sweep_cost: f64,
    pub coarsened_sweep_cost: f64,
    pub spin_sweep_cost: f64,
}

impl ScheduleCost {
    pub fn analyze(
        levels: &LevelSchedule,
        sched: &CoarsenedSchedule,
        tri: &TriFactors,
    ) -> ScheduleCost {
        let n = levels.n();
        let nlv = levels.num_levels();
        let lp = tri.lower.row_ptr();
        let up = tri.upper.row_ptr();
        let row_nnz = |p: &[u32], i: usize| (p[i + 1] - p[i]) as usize;

        let mut rows_per_level = Vec::new();
        let mut max_level_rows = 0usize;
        let mut max_level_nnz = 0usize;
        for l in 0..nlv {
            let rows = levels.level(l);
            let bucket = usize::BITS as usize - 1 - rows.len().leading_zeros() as usize;
            if rows_per_level.len() <= bucket {
                rows_per_level.resize(bucket + 1, 0);
            }
            rows_per_level[bucket] += 1;
            max_level_rows = max_level_rows.max(rows.len());
            let nnz: usize = rows
                .iter()
                .map(|&i| row_nnz(lp, i as usize) + row_nnz(up, i as usize))
                .sum();
            max_level_nnz = max_level_nnz.max(nnz);
        }
        let total_nnz = tri.lower.nnz() + tri.upper.nnz();
        let mean_level_nnz = if nlv == 0 { 0.0 } else { total_nnz as f64 / nlv as f64 };

        let stages = sched.stages();
        let serial_segments =
            sched.segments.iter().filter(|s| s.mode == SegmentMode::Serial).count();
        let serialized_rows: usize = sched
            .segments
            .iter()
            .filter(|s| s.mode == SegmentMode::Serial)
            .map(|s| sched.level_ptr[s.level_hi] - sched.level_ptr[s.level_lo])
            .sum();

        // One sweep touches half the factor (one triangle) plus a diagonal
        // scale per row.
        let work = 0.5 * total_nnz as f64 + n as f64;
        let barrier_sweep_cost = work + nlv.saturating_sub(1) as f64 * BARRIER_COST;
        let coarsened_sweep_cost = work + stages.saturating_sub(1) as f64 * BARRIER_COST;
        // Spinning probes one flag per strict-triangle nonzero.
        let spin_sweep_cost = work + SPIN_COST * 0.5 * total_nnz as f64;

        ScheduleCost {
            levels: nlv,
            rows_per_level,
            max_level_rows,
            total_nnz,
            mean_level_nnz,
            max_level_nnz,
            coarsened_stages: stages,
            serial_segments,
            serialized_rows,
            predicted_syncs_per_sweep: stages.saturating_sub(1),
            barrier_sweep_cost,
            coarsened_sweep_cost,
            spin_sweep_cost,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::ic0::ic0;
    use crate::schedule::coarsen::{coarsen, CoarsenParams};
    use crate::sparse::coo::Coo;
    use crate::sparse::csr::Csr;

    fn grid(nx: usize, ny: usize) -> Csr {
        let idx = |x: usize, y: usize| y * nx + x;
        let mut c = Coo::new(nx * ny);
        for y in 0..ny {
            for x in 0..nx {
                c.push(idx(x, y), idx(x, y), 4.0);
                if x + 1 < nx {
                    c.push_sym(idx(x, y), idx(x + 1, y), -1.0);
                }
                if y + 1 < ny {
                    c.push_sym(idx(x, y), idx(x, y + 1), -1.0);
                }
            }
        }
        c.to_csr()
    }

    fn factors(a: &Csr) -> TriFactors {
        TriFactors::from_ic(&ic0(a, 0.0).unwrap())
    }

    #[test]
    fn coarsening_never_costs_more_than_barrier_per_level() {
        for (nx, ny, min_rows) in [(7, 5, 64), (24, 24, 10), (16, 16, 0)] {
            let tri = factors(&grid(nx, ny));
            let lv = LevelSchedule::build(&tri);
            let sched = coarsen(&lv, &tri, &CoarsenParams { min_rows, min_nnz: 0 });
            let cost = ScheduleCost::analyze(&lv, &sched, &tri);
            assert!(
                cost.coarsened_sweep_cost <= cost.barrier_sweep_cost,
                "{nx}x{ny}: coarsened {} > barrier {}",
                cost.coarsened_sweep_cost,
                cost.barrier_sweep_cost
            );
            assert_eq!(cost.predicted_syncs_per_sweep, cost.coarsened_stages - 1);
        }
    }

    #[test]
    fn histogram_sums_to_level_count() {
        let tri = factors(&grid(24, 24));
        let lv = LevelSchedule::build(&tri);
        let sched = coarsen(&lv, &tri, &CoarsenParams::default());
        let cost = ScheduleCost::analyze(&lv, &sched, &tri);
        assert_eq!(cost.levels, lv.num_levels());
        assert_eq!(cost.rows_per_level.iter().sum::<usize>(), cost.levels);
        assert_eq!(cost.max_level_rows, 24); // widest anti-diagonal
        assert_eq!(cost.total_nnz, tri.lower.nnz() + tri.upper.nnz());
        assert!(cost.mean_level_nnz > 0.0);
        assert!(cost.max_level_nnz as f64 >= cost.mean_level_nnz);
    }

    #[test]
    fn fully_coarsened_schedule_predicts_zero_syncs() {
        let tri = factors(&grid(7, 5));
        let lv = LevelSchedule::build(&tri);
        let sched = coarsen(&lv, &tri, &CoarsenParams::default());
        let cost = ScheduleCost::analyze(&lv, &sched, &tri);
        assert_eq!(cost.coarsened_stages, 1);
        assert_eq!(cost.predicted_syncs_per_sweep, 0);
        assert_eq!(cost.serial_segments, 1);
        assert_eq!(cost.serialized_rows, 35);
        // With no barriers the coarsened cost is the bare work term,
        // strictly below both alternatives on this multi-level matrix.
        assert!(cost.coarsened_sweep_cost < cost.barrier_sweep_cost);
        assert!(cost.coarsened_sweep_cost < cost.spin_sweep_cost);
    }
}
