//! Perf-regression gate over the `BENCH_*.json` perf-trajectory artifacts.
//!
//! CI snapshots the committed artifacts into a baseline directory, runs the
//! quick benches (which overwrite the repo-root copies with fresh
//! measurements), then runs
//!
//! ```text
//! bench_gate --baseline bench_baseline --current .
//! ```
//!
//! which compares every numeric metric it recognizes and exits non-zero
//! when a measured metric regressed beyond the tolerance (`--tol`, or the
//! `HBMC_BENCH_TOL` env var; default 0.15 = 15%, generous because quick
//! benches on shared CI runners are noisy).
//!
//! Metric direction is inferred from the key name: `*_seconds` / `*_us` /
//! `*overhead_ratio` regress upward; `*_per_sec` / `*_gflops` / `*_gbps` /
//! `speedup` / `coverage` regress downward; everything else (counts,
//! analytic model strings, labels) is informational. `null` on either side
//! skips the metric — committed baselines authored without a toolchain
//! carry null timings until the documented refresh (see README) replaces
//! them.
//!
//! **Auto-seed mode:** a baseline file whose top-level `provenance` does
//! not start with `"measured"` has never held real numbers on this branch;
//! the gate reports it as seeded-not-compared and stays green, so the
//! first CI run after adding a bench cannot fail against a schema stub.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use hbmc::util::json::Json;

#[derive(Clone, Copy, PartialEq)]
enum Direction {
    /// Larger is a regression (times, waits, overhead ratios).
    UpIsWorse,
    /// Smaller is a regression (throughput, bandwidth, coverage).
    DownIsWorse,
    /// Informational only.
    Skip,
}

fn direction(key: &str) -> Direction {
    if key.ends_with("_seconds") || key.ends_with("_us") || key.ends_with("overhead_ratio") {
        Direction::UpIsWorse
    } else if key.ends_with("_per_sec")
        || key.ends_with("_gflops")
        || key.ends_with("_gbps")
        || key.ends_with("speedup")
        || key.ends_with("coverage")
    {
        Direction::DownIsWorse
    } else {
        Direction::Skip
    }
}

struct Gate {
    tol: f64,
    checked: usize,
    improved: usize,
    regressions: Vec<String>,
}

impl Gate {
    fn leaf(&mut self, file: &str, path: &str, key: &str, base: f64, cur: f64) {
        let dir = direction(key);
        if dir == Direction::Skip || !base.is_finite() || !cur.is_finite() || base <= 0.0 {
            return;
        }
        self.checked += 1;
        let ratio = cur / base;
        let (regressed, improved) = match dir {
            Direction::UpIsWorse => (ratio > 1.0 + self.tol, ratio < 1.0),
            Direction::DownIsWorse => (ratio < 1.0 - self.tol, ratio > 1.0),
            Direction::Skip => unreachable!(),
        };
        if regressed {
            self.regressions.push(format!(
                "{file}: {path} regressed {base:.6} -> {cur:.6} ({:+.1}% vs tol {:.0}%)",
                100.0 * (ratio - 1.0),
                100.0 * self.tol
            ));
        } else if improved {
            self.improved += 1;
        }
    }

    /// Structural walk: objects by key, arrays by index (bench emitters are
    /// deterministic), numbers as gated leaves. `null` anywhere skips.
    fn walk(&mut self, file: &str, path: &str, key: &str, base: &Json, cur: &Json) {
        match (base, cur) {
            (Json::Num(b), Json::Num(c)) => self.leaf(file, path, key, *b, *c),
            (Json::Obj(members), _) => {
                for (k, bv) in members {
                    match cur.get(k) {
                        Some(cv) => self.walk(file, &format!("{path}.{k}"), k, bv, cv),
                        None if direction(k) != Direction::Skip && !bv.is_null() => {
                            self.regressions
                                .push(format!("{file}: {path}.{k} disappeared from current run"));
                        }
                        None => {}
                    }
                }
            }
            (Json::Arr(bs), Json::Arr(cs)) => {
                for (i, bv) in bs.iter().enumerate() {
                    let Some(cv) = cs.get(i) else { continue };
                    // Prefer the entry's own label for readable messages.
                    let name = ["label", "strategy"]
                        .iter()
                        .find_map(|k| bv.get(k).and_then(Json::as_str))
                        .map(str::to_string)
                        .unwrap_or_else(|| i.to_string());
                    self.walk(file, &format!("{path}[{name}]"), key, bv, cv);
                }
            }
            _ => {} // null vs number, type drift, strings: informational
        }
    }
}

fn arg_value(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn bench_files(dir: &Path) -> std::io::Result<Vec<PathBuf>> {
    let mut out: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
        })
        .collect();
    out.sort();
    Ok(out)
}

fn run() -> Result<bool, String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let usage = "usage: bench_gate --baseline <dir> --current <dir> [--tol X]";
    let baseline = PathBuf::from(arg_value(&args, "--baseline").ok_or(usage)?);
    let current = PathBuf::from(arg_value(&args, "--current").ok_or("--current <dir> required")?);
    let tol = match arg_value(&args, "--tol").or_else(|| std::env::var("HBMC_BENCH_TOL").ok()) {
        Some(s) => s.parse::<f64>().map_err(|_| format!("bad tolerance {s:?}"))?,
        None => 0.15,
    };
    let mut gate = Gate { tol, checked: 0, improved: 0, regressions: Vec::new() };
    let mut seeded = 0usize;
    let files = bench_files(&baseline)
        .map_err(|e| format!("reading baseline dir {}: {e}", baseline.display()))?;
    if files.is_empty() {
        return Err(format!("no BENCH_*.json baselines in {}", baseline.display()));
    }
    for bpath in files {
        let name = bpath.file_name().and_then(|n| n.to_str()).unwrap_or("?").to_string();
        let btext = std::fs::read_to_string(&bpath)
            .map_err(|e| format!("reading {}: {e}", bpath.display()))?;
        let base = Json::parse(&btext).map_err(|e| format!("{name} (baseline): {e}"))?;
        let measured = base
            .get("provenance")
            .and_then(Json::as_str)
            .is_some_and(|p| p.starts_with("measured"));
        if !measured {
            println!("bench-gate: {name}: baseline not yet measured — auto-seed, not compared");
            seeded += 1;
            continue;
        }
        let cpath = current.join(&name);
        let Ok(ctext) = std::fs::read_to_string(&cpath) else {
            let missing = format!(
                "{name}: measured baseline but no current run at {}",
                cpath.display()
            );
            gate.regressions.push(missing);
            continue;
        };
        let cur = Json::parse(&ctext).map_err(|e| format!("{name} (current): {e}"))?;
        gate.walk(&name, "$", "", &base, &cur);
    }
    for r in &gate.regressions {
        eprintln!("bench-gate: REGRESSION {r}");
    }
    println!(
        "bench-gate: {} metric(s) checked, {} improved, {} regressed, {} file(s) auto-seeded \
         (tol {:.0}%)",
        gate.checked,
        gate.improved,
        gate.regressions.len(),
        seeded,
        100.0 * gate.tol
    );
    Ok(gate.regressions.is_empty())
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::FAILURE,
        Err(e) => {
            eprintln!("bench-gate: error: {e}");
            ExitCode::from(2)
        }
    }
}
