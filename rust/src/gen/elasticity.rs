//! 3D block-elasticity generator — the `Audikw_1`-class substrate:
//! structural problem with 3 dofs per node, a 27-point node stencil
//! (→ ~81 nnz per row) and injected heavy rows reproducing the row-length
//! imbalance that inflates SELL padding on this dataset (paper §5.2.2:
//! +40% processed elements vs CRS).
//!
//! Assembly is a *block graph Laplacian* of truss-like edge stiffnesses
//! `K_ab = s·I + n⊗n` (n ≈ edge direction): `xᵀ A x = Σ (x_a−x_b)ᵀ K_ab
//! (x_a−x_b) ≥ 0`, so the operator is exactly PSD with rigid-body
//! translations/rotations as near-null modes — the physics that makes the
//! real Audikw_1 need >1000 ICCG iterations — plus a small `ε·diag`
//! regularization for strict definiteness.

use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::util::rng::Rng;

/// 3-dof-per-node elasticity-like operator on an `nx × ny × nz` grid.
/// `heavy_frac` of the nodes receive extra long-range couplings
/// (constraint/contact-like), creating heavy rows.
pub fn elasticity3d(nx: usize, ny: usize, nz: usize, heavy_frac: f64, seed: u64) -> Csr {
    let nodes = nx * ny * nz;
    let n = 3 * nodes;
    let mut rng = Rng::new(seed);
    let nidx = |x: usize, y: usize, z: usize| (z * ny + y) * nx + x;
    let mut coo = Coo::with_capacity(n, 85 * n);

    // Edge stiffness K = s·I + n⊗n along the (noisy) edge direction;
    // `aniso` models thin/stretched elements (z much stiffer), which is
    // where structural matrices get their worst conditioning.
    let couple = |coo: &mut Coo, rng: &mut Rng, a: usize, b: usize, aniso: f64, dir: [f64; 3]| {
        let s = 0.02 + 0.02 * rng.f64();
        let norm = (dir[0] * dir[0] + dir[1] * dir[1] + dir[2] * dir[2]).sqrt().max(1e-12);
        let u = [
            (dir[0] / norm + 0.05 * rng.normal()) * aniso.sqrt(),
            (dir[1] / norm + 0.05 * rng.normal()) * aniso.sqrt(),
            (dir[2] / norm + 0.05 * rng.normal()) * aniso.sqrt(),
        ];
        for p in 0..3 {
            for q in 0..3 {
                let kpq = if p == q { s * aniso } else { 0.0 } + u[p] * u[q];
                // Block Laplacian: −K off-diagonal, +K on both diagonal
                // blocks (keeps A = Σ incidence-quadratic forms, PSD).
                coo.push(3 * a + p, 3 * b + q, -kpq);
                coo.push(3 * b + q, 3 * a + p, -kpq);
                coo.push(3 * a + p, 3 * a + q, kpq);
                coo.push(3 * b + p, 3 * b + q, kpq);
            }
        }
    };

    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let i = nidx(x, y, z);
                // Half of the 26 neighbors (visit each pair once).
                for dz in 0..=1i64 {
                    for dy in -1i64..=1 {
                        for dx in -1i64..=1 {
                            if dz == 0 && (dy < 0 || (dy == 0 && dx <= 0)) {
                                continue;
                            }
                            let (xx, yy, zz) = (x as i64 + dx, y as i64 + dy, z as i64 + dz);
                            if xx < 0 || yy < 0 || xx >= nx as i64 || yy >= ny as i64
                                || zz >= nz as i64
                            {
                                continue;
                            }
                            let j = nidx(xx as usize, yy as usize, zz as usize);
                            let aniso = if dz != 0 { 40.0 } else { 1.0 };
                            couple(
                                &mut coo,
                                &mut rng,
                                i,
                                j,
                                aniso,
                                [dx as f64, dy as f64, dz as f64],
                            );
                        }
                    }
                }
            }
        }
    }

    // Heavy rows: a few nodes couple to extra random nodes (contact /
    // constraint clusters). Sized so SELL-8 padding lands in the paper's
    // +40% regime for the audikw_1 registry entry (§5.2.2).
    let heavies = (heavy_frac * nodes as f64) as usize;
    for _ in 0..heavies {
        let i = rng.below(nodes);
        let extra = 24 + rng.below(48);
        for _ in 0..extra {
            let j = rng.below(nodes);
            if i != j {
                let dir = [rng.normal(), rng.normal(), rng.normal()];
                couple(&mut coo, &mut rng, i, j, 1.0, dir);
            }
        }
    }

    // Strict definiteness: tiny relative diagonal regularization.
    let a0 = coo.to_csr();
    let mut coo2 = Coo::with_capacity(n, a0.nnz() + n);
    for i in 0..n {
        let (cols, vals) = a0.row(i);
        for (c, v) in cols.iter().zip(vals) {
            coo2.push(i, *c as usize, *v);
        }
        let dii = a0.get(i, i).unwrap_or(0.0);
        coo2.push(i, i, 1e-6 * (1.0 + dii));
    }
    coo2.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::sell::Sell;

    #[test]
    fn shape_and_symmetry() {
        let a = elasticity3d(4, 4, 3, 0.0, 17);
        assert_eq!(a.n(), 144);
        assert!(a.is_symmetric(1e-10));
    }

    #[test]
    fn interior_rows_are_dense() {
        let a = elasticity3d(5, 5, 5, 0.0, 19);
        // Interior node: (26 neighbors + self) × 3 dofs = 81 per row.
        let interior = 3 * ((2 * 5 + 2) * 5 + 2);
        assert_eq!(a.row_len(interior), 81);
    }

    #[test]
    fn operator_is_positive_definite_under_cg() {
        // PSD + ε-regularization: CG with IC must converge.
        let a = elasticity3d(4, 4, 3, 0.02, 23);
        let mut b = vec![0.0; a.n()];
        a.mul_vec(&vec![1.0; a.n()], &mut b);
        let cfg = crate::config::SolverConfig {
            ordering: crate::config::OrderingKind::Natural,
            rtol: 1e-7,
            max_iters: 20_000,
            ..Default::default()
        };
        let rep = crate::coordinator::driver::solve(&a, &b, &cfg).unwrap();
        assert!(rep.converged);
    }

    #[test]
    fn heavy_rows_inflate_sell_padding() {
        let plain = elasticity3d(6, 6, 4, 0.0, 23);
        let heavy = elasticity3d(6, 6, 4, 0.08, 23);
        let s_plain = Sell::from_csr(&plain, 8);
        let s_heavy = Sell::from_csr(&heavy, 8);
        let o_plain = s_plain.overhead_vs(plain.nnz());
        let o_heavy = s_heavy.overhead_vs(heavy.nnz());
        assert!(
            o_heavy > o_plain + 0.02,
            "heavy rows should inflate SELL overhead: {o_plain:.3} vs {o_heavy:.3}"
        );
    }

    #[test]
    fn diagonally_factorable_with_ic0() {
        let a = elasticity3d(3, 3, 3, 0.05, 29);
        let f = crate::factor::ic0::ic0_auto(&a, 0.0);
        assert!(f.is_ok(), "IC must factor (possibly auto-shifted)");
    }
}
