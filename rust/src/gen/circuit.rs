//! Circuit-network generator — the `G3_circuit`-class substrate: a large
//! sparse SPD graph Laplacian with mostly grid-like degree plus a sprinkle
//! of longer-range connections (vias/global nets), giving the irregular
//! degree mix that makes gather-heavy substitution rows common.

use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::util::rng::Rng;

/// Conductance network: 2D grid of resistors plus `extra_frac · n` random
/// long-range resistors; Laplacian + small diagonal (ground leakage).
pub fn circuit_network(nx: usize, ny: usize, extra_frac: f64, seed: u64) -> Csr {
    let n = nx * ny;
    let mut rng = Rng::new(seed);
    let idx = |x: usize, y: usize| y * nx + x;
    let mut coo = Coo::with_capacity(n, 5 * n + (extra_frac * n as f64) as usize * 2);
    let mut diag = vec![0.0f64; n];
    let resistor = |coo: &mut Coo, rng: &mut Rng, i: usize, j: usize, d: &mut [f64]| {
        // Conductances spread over decades, as in power/ground networks.
        let g = 10f64.powf(rng.range_f64(-1.0, 1.0));
        coo.push_sym(i, j, -g);
        d[i] += g;
        d[j] += g;
    };
    for y in 0..ny {
        for x in 0..nx {
            if x + 1 < nx {
                resistor(&mut coo, &mut rng, idx(x, y), idx(x + 1, y), &mut diag);
            }
            if y + 1 < ny {
                resistor(&mut coo, &mut rng, idx(x, y), idx(x, y + 1), &mut diag);
            }
        }
    }
    let extras = (extra_frac * n as f64) as usize;
    for _ in 0..extras {
        let i = rng.below(n);
        let j = rng.below(n);
        if i != j {
            resistor(&mut coo, &mut rng, i, j, &mut diag);
        }
    }
    // Tiny ground-leakage keeps the Laplacian SPD while leaving it badly
    // conditioned — the real G3_circuit takes >1000 ICCG iterations.
    for (i, d) in diag.iter().enumerate() {
        coo.push(i, i, d + 3e-6 * (1.0 + d));
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn symmetric_and_dominant() {
        let a = circuit_network(20, 20, 0.05, 11);
        assert!(a.is_symmetric(1e-12));
        for i in 0..a.n() {
            let (cols, vals) = a.row(i);
            let off: f64 = cols
                .iter()
                .zip(vals)
                .filter(|(c, _)| **c as usize != i)
                .map(|(_, v)| v.abs())
                .sum();
            assert!(a.get(i, i).unwrap() > off, "row {i}");
        }
    }

    #[test]
    fn degree_is_irregular_with_extras() {
        let a = circuit_network(30, 30, 0.2, 13);
        let lens: Vec<usize> = (0..a.n()).map(|i| a.row_len(i)).collect();
        let max = *lens.iter().max().unwrap();
        let min = *lens.iter().min().unwrap();
        assert!(max > min + 2, "degrees too uniform: {min}..{max}");
    }

    #[test]
    fn no_extras_gives_grid_laplacian() {
        let a = circuit_network(10, 10, 0.0, 1);
        assert_eq!(a.nnz(), 100 + 2 * (2 * 10 * 9));
    }
}
