//! Finite-difference stencil generators: 5-point (2D), 7-point and
//! 27-point (3D) Laplacians with optional heterogeneous coefficients.
//! These are the canonical parallel-ordering test problems (paper Fig. 4.5
//! uses the five-point stencil).

use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::util::rng::Rng;

/// 2D 5-point Laplacian on an `nx × ny` grid with per-cell conductivity.
/// `sigma_lognorm = 0` gives the constant-coefficient operator.
pub fn laplace2d(nx: usize, ny: usize, sigma_lognorm: f64, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let idx = |x: usize, y: usize| y * nx + x;
    let n = nx * ny;
    // Edge conductivities from the harmonic pairing of cell coefficients.
    let coeff = |rng: &mut Rng| {
        if sigma_lognorm == 0.0 {
            1.0
        } else {
            rng.log_normal(sigma_lognorm)
        }
    };
    let mut coo = Coo::with_capacity(n, 5 * n);
    let mut diag = vec![0.0f64; n];
    for y in 0..ny {
        for x in 0..nx {
            if x + 1 < nx {
                let c = coeff(&mut rng);
                coo.push_sym(idx(x, y), idx(x + 1, y), -c);
                diag[idx(x, y)] += c;
                diag[idx(x + 1, y)] += c;
            }
            if y + 1 < ny {
                let c = coeff(&mut rng);
                coo.push_sym(idx(x, y), idx(x, y + 1), -c);
                diag[idx(x, y)] += c;
                diag[idx(x, y + 1)] += c;
            }
        }
    }
    for (i, d) in diag.iter().enumerate() {
        // Dirichlet-like regularization keeps the operator SPD.
        coo.push(i, i, d + 1e-2);
    }
    coo.to_csr()
}

/// 2D parabolic (implicit time step): `M/Δt + K` — strongly diagonally
/// dominant, the `Parabolic_fem`-class problem.
pub fn parabolic2d(nx: usize, ny: usize, inv_dt: f64, seed: u64) -> Csr {
    let k = laplace2d(nx, ny, 0.3, seed);
    let n = k.n();
    let mut coo = Coo::with_capacity(n, k.nnz() + n);
    for i in 0..n {
        let (cols, vals) = k.row(i);
        for (c, v) in cols.iter().zip(vals) {
            coo.push(i, *c as usize, *v);
        }
        coo.push(i, i, inv_dt);
    }
    coo.to_csr()
}

/// 3D 7-point Laplacian on `nx × ny × nz`.
pub fn laplace3d_7pt(nx: usize, ny: usize, nz: usize) -> Csr {
    let idx = |x: usize, y: usize, z: usize| (z * ny + y) * nx + x;
    let n = nx * ny * nz;
    let mut coo = Coo::with_capacity(n, 7 * n);
    let mut diag = vec![0.0f64; n];
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let i = idx(x, y, z);
                if x + 1 < nx {
                    coo.push_sym(i, idx(x + 1, y, z), -1.0);
                    diag[i] += 1.0;
                    diag[idx(x + 1, y, z)] += 1.0;
                }
                if y + 1 < ny {
                    coo.push_sym(i, idx(x, y + 1, z), -1.0);
                    diag[i] += 1.0;
                    diag[idx(x, y + 1, z)] += 1.0;
                }
                if z + 1 < nz {
                    coo.push_sym(i, idx(x, y, z + 1), -1.0);
                    diag[i] += 1.0;
                    diag[idx(x, y, z + 1)] += 1.0;
                }
            }
        }
    }
    for (i, d) in diag.iter().enumerate() {
        coo.push(i, i, d + 1e-2);
    }
    coo.to_csr()
}

/// 3D 27-point stencil (all neighbors in the unit cube) — the dense-stencil
/// substrate under the `Audikw_1`-class generator.
pub fn stencil27(nx: usize, ny: usize, nz: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let idx = |x: usize, y: usize, z: usize| (z * ny + y) * nx + x;
    let n = nx * ny * nz;
    let mut coo = Coo::with_capacity(n, 27 * n);
    let mut diag = vec![0.0f64; n];
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let i = idx(x, y, z);
                for dz in 0..=1usize {
                    for dy in -(1i64)..=1 {
                        for dx in -(1i64)..=1 {
                            if dz == 0 && (dy < 0 || (dy == 0 && dx <= 0)) {
                                continue; // visit each pair once
                            }
                            let (xx, yy, zz) =
                                (x as i64 + dx, y as i64 + dy, z as i64 + dz as i64);
                            if xx < 0 || yy < 0 || xx >= nx as i64 || yy >= ny as i64
                                || zz >= nz as i64
                            {
                                continue;
                            }
                            let j = idx(xx as usize, yy as usize, zz as usize);
                            let w = 0.3 + 0.2 * rng.f64();
                            coo.push_sym(i, j, -w);
                            diag[i] += w;
                            diag[j] += w;
                        }
                    }
                }
            }
        }
    }
    for (i, d) in diag.iter().enumerate() {
        coo.push(i, i, d + 1e-2);
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laplace2d_is_spd_shaped() {
        let a = laplace2d(10, 8, 0.0, 1);
        assert_eq!(a.n(), 80);
        assert!(a.is_symmetric(1e-12));
        // Diagonally dominant by construction.
        for i in 0..a.n() {
            let (cols, vals) = a.row(i);
            let off: f64 = cols
                .iter()
                .zip(vals)
                .filter(|(c, _)| **c as usize != i)
                .map(|(_, v)| v.abs())
                .sum();
            assert!(a.get(i, i).unwrap() >= off, "row {i} not dominant");
        }
    }

    #[test]
    fn laplace2d_interior_has_5_entries() {
        let a = laplace2d(5, 5, 0.0, 1);
        assert_eq!(a.row_len(12), 5); // center node
        assert_eq!(a.row_len(0), 3); // corner
    }

    #[test]
    fn heterogeneous_coefficients_vary() {
        let a = laplace2d(6, 6, 1.0, 7);
        let vals: Vec<f64> = (0..a.n())
            .flat_map(|i| {
                let (cols, vals) = a.row(i);
                cols.iter()
                    .zip(vals)
                    .filter(|(c, _)| (**c as usize) != i)
                    .map(|(_, v)| -*v)
                    .collect::<Vec<_>>()
            })
            .collect();
        let min = vals.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = vals.iter().cloned().fold(0.0, f64::max);
        assert!(max / min > 2.0, "no heterogeneity: {min}..{max}");
    }

    #[test]
    fn parabolic_strengthens_diagonal() {
        let k = laplace2d(6, 6, 0.3, 3);
        let p = parabolic2d(6, 6, 100.0, 3);
        for i in 0..k.n() {
            assert!(p.get(i, i).unwrap() > k.get(i, i).unwrap() + 99.0);
        }
    }

    #[test]
    fn laplace3d_shape() {
        let a = laplace3d_7pt(4, 4, 4);
        assert_eq!(a.n(), 64);
        assert!(a.is_symmetric(1e-12));
        // interior node has 7 entries
        let i = (1 * 4 + 1) * 4 + 1;
        assert_eq!(a.row_len(i), 7);
    }

    #[test]
    fn stencil27_interior_has_27() {
        let a = stencil27(4, 4, 4, 5);
        let i = (1 * 4 + 1) * 4 + 1;
        assert_eq!(a.row_len(i), 27);
        assert!(a.is_symmetric(1e-12));
    }
}
