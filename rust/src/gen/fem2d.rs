//! 2D unstructured-style FEM graph generator — the `Thermal2`-class
//! substrate (unstructured thermal FEM: ~7 nnz/row, irregular node
//! numbering, heterogeneous conductivity).
//!
//! A structured triangulation (grid + one diagonal per cell) gives each
//! interior node degree ~6; a random relabeling of the nodes then destroys
//! the banded structure the way an unstructured mesher's numbering does,
//! which is what stresses the ordering heuristics.

use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::util::rng::Rng;

/// Triangulated-grid thermal problem with log-normal conductivity jumps
/// and randomized node numbering.
pub fn thermal_fem2d(nx: usize, ny: usize, sigma_lognorm: f64, seed: u64) -> Csr {
    let n = nx * ny;
    let mut rng = Rng::new(seed);

    // Random node relabeling (the "unstructured numbering").
    let mut label: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut label);

    let idx = |x: usize, y: usize| label[y * nx + x] as usize;
    let mut coo = Coo::with_capacity(n, 9 * n);
    let mut diag = vec![0.0f64; n];
    let edge = |coo: &mut Coo, rng: &mut Rng, i: usize, j: usize, d: &mut [f64]| {
        let c = rng.log_normal(sigma_lognorm);
        coo.push_sym(i, j, -c);
        d[i] += c;
        d[j] += c;
    };
    for y in 0..ny {
        for x in 0..nx {
            if x + 1 < nx {
                edge(&mut coo, &mut rng, idx(x, y), idx(x + 1, y), &mut diag);
            }
            if y + 1 < ny {
                edge(&mut coo, &mut rng, idx(x, y), idx(x, y + 1), &mut diag);
            }
            // Diagonal of the triangulation.
            if x + 1 < nx && y + 1 < ny {
                edge(&mut coo, &mut rng, idx(x, y), idx(x + 1, y + 1), &mut diag);
            }
        }
    }
    // Weak absorption term: SPD but ill-conditioned, like Thermal2.
    for (i, d) in diag.iter().enumerate() {
        coo.push(i, i, d + 1e-5);
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_symmetry() {
        let a = thermal_fem2d(12, 10, 0.5, 3);
        assert_eq!(a.n(), 120);
        assert!(a.is_symmetric(1e-12));
    }

    #[test]
    fn average_degree_matches_triangulation() {
        let a = thermal_fem2d(30, 30, 0.5, 4);
        let avg = a.nnz() as f64 / a.n() as f64;
        // Interior nodes: 6 neighbors + diagonal ⇒ ~7 nnz/row.
        assert!((6.0..7.5).contains(&avg), "avg={avg}");
    }

    #[test]
    fn numbering_is_scrambled() {
        // With random labels, consecutive indices are rarely adjacent:
        // measure bandwidth — should be large.
        let a = thermal_fem2d(20, 20, 0.5, 5);
        let mut max_band = 0usize;
        for i in 0..a.n() {
            let (cols, _) = a.row(i);
            for &c in cols {
                max_band = max_band.max(i.abs_diff(c as usize));
            }
        }
        assert!(max_band > a.n() / 2, "bandwidth {max_band} too small — not scrambled");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = thermal_fem2d(8, 8, 0.5, 9);
        let b = thermal_fem2d(8, 8, 0.5, 9);
        assert_eq!(a, b);
        let c = thermal_fem2d(8, 8, 0.5, 10);
        assert_ne!(a, c);
    }
}
