//! Synthetic problem generators.
//!
//! The paper evaluates on one FEM-assembled system (`Ieej`) and four
//! SuiteSparse matrices. Those files are not available offline, so each
//! dataset has a generator reproducing its *structural class* — dimension
//! regime, nnz/row, degree irregularity, definiteness — per the
//! substitution table in `DESIGN.md` §3. [`suite`] is the named registry;
//! the individual modules are reusable substrates (grid stencils, FEM
//! graphs, circuit graphs, elasticity blocks, edge elements).

pub mod circuit;
pub mod edgefem;
pub mod elasticity;
pub mod fdm;
pub mod fem2d;
pub mod suite;

use crate::sparse::csr::Csr;

/// A generated test problem.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub matrix: Csr,
    /// Right-hand side (`A·1` by default so the exact solution is 1).
    pub b: Vec<f64>,
    /// Diagonal shift the paper's protocol uses for this dataset
    /// (0.3 for Ieej, 0 otherwise).
    pub shift: f64,
}

impl Dataset {
    /// Build with `b = A·1`.
    pub fn with_unit_solution(name: &str, matrix: Csr, shift: f64) -> Dataset {
        let mut b = vec![0.0; matrix.n()];
        matrix.mul_vec(&vec![1.0; matrix.n()], &mut b);
        Dataset { name: name.to_string(), matrix, b, shift }
    }

    pub fn n(&self) -> usize {
        self.matrix.n()
    }

    pub fn nnz(&self) -> usize {
        self.matrix.nnz()
    }

    pub fn nnz_per_row(&self) -> f64 {
        self.nnz() as f64 / self.n() as f64
    }
}
