//! Named dataset registry — the offline stand-ins for the paper's five
//! test problems (Table 5.1), at three scales. See `DESIGN.md` §3 for the
//! substitution rationale per dataset; the *class* properties (nnz/row,
//! irregularity, definiteness) are matched, not the exact files.

use crate::config::Scale;
use crate::error::{HbmcError, Result};
use crate::gen::{circuit, edgefem, elasticity, fdm, fem2d, Dataset};

/// Paper dataset names in table order.
pub const NAMES: [&str; 5] = ["thermal2", "parabolic_fem", "g3_circuit", "audikw_1", "ieej"];

/// Generate a dataset by (case-insensitive) paper name.
pub fn dataset(name: &str, scale: Scale) -> Dataset {
    try_dataset(name, scale).expect("unknown dataset")
}

/// Fallible lookup.
pub fn try_dataset(name: &str, scale: Scale) -> Result<Dataset> {
    let key = name.to_ascii_lowercase();
    Ok(match key.as_str() {
        // Thermal2: unstructured 2D thermal FEM, ~7 nnz/row, 1.23M dims in
        // the paper.
        "thermal2" => {
            let (nx, ny) = match scale {
                Scale::Tiny => (40, 40),
                Scale::Small => (260, 260),
                Scale::Full => (640, 640),
            };
            Dataset::with_unit_solution(
                "thermal2",
                fem2d::thermal_fem2d(nx, ny, 0.8, 0x7e41),
                0.0,
            )
        }
        // Parabolic_fem: CFD/parabolic, strongly diagonally dominant,
        // 3.7M nnz over 526k dims (7 nnz/row).
        "parabolic_fem" => {
            let (nx, ny) = match scale {
                Scale::Tiny => (40, 40),
                Scale::Small => (230, 230),
                Scale::Full => (560, 560),
            };
            Dataset::with_unit_solution(
                "parabolic_fem",
                fdm::parabolic2d(nx, ny, 0.05, 0x9a7a),
                0.0,
            )
        }
        // G3_circuit: circuit Laplacian, irregular degrees.
        "g3_circuit" => {
            let (nx, ny) = match scale {
                Scale::Tiny => (45, 45),
                Scale::Small => (300, 300),
                Scale::Full => (720, 720),
            };
            Dataset::with_unit_solution(
                "g3_circuit",
                circuit::circuit_network(nx, ny, 0.06, 0x63c1),
                0.0,
            )
        }
        // Audikw_1: 3D structural, ~82 nnz/row, heavy-row imbalance.
        "audikw_1" => {
            let (nx, ny, nz) = match scale {
                Scale::Tiny => (6, 6, 5),
                Scale::Small => (22, 22, 20),
                Scale::Full => (42, 42, 40),
            };
            Dataset::with_unit_solution(
                "audikw_1",
                elasticity::elasticity3d(nx, ny, nz, 0.10, 0xa0d1),
                0.0,
            )
        }
        // Ieej: edge-FEM eddy current, semi-definite → shifted IC σ = 0.3.
        "ieej" => {
            let (nx, ny, nz) = match scale {
                Scale::Tiny => (7, 7, 7),
                Scale::Small => (26, 26, 26),
                Scale::Full => (46, 46, 46),
            };
            Dataset::with_unit_solution(
                "ieej",
                edgefem::curl_curl3d(nx, ny, nz, 0.5, 1e-6, 0x1ee1),
                0.3,
            )
        }
        _ => {
            return Err(HbmcError::UnknownMatrix(format!(
                "dataset {name:?}; known: {NAMES:?}"
            )))
        }
    })
}

/// All five paper datasets at a given scale.
pub fn all(scale: Scale) -> Vec<Dataset> {
    NAMES.iter().map(|n| dataset(n, scale)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_all_names() {
        for name in NAMES {
            let d = dataset(name, Scale::Tiny);
            assert_eq!(d.name, name);
            assert!(d.n() > 500, "{name} too small: {}", d.n());
            assert!(d.matrix.is_symmetric(1e-9), "{name} not symmetric");
        }
        assert!(try_dataset("nope", Scale::Tiny).is_err());
    }

    #[test]
    fn ieej_uses_shift() {
        let d = dataset("ieej", Scale::Tiny);
        assert_eq!(d.shift, 0.3);
        assert_eq!(dataset("thermal2", Scale::Tiny).shift, 0.0);
    }

    #[test]
    fn audikw_has_highest_nnz_per_row() {
        let aud = dataset("audikw_1", Scale::Tiny);
        for other in ["thermal2", "parabolic_fem", "g3_circuit"] {
            let d = dataset(other, Scale::Tiny);
            assert!(
                aud.nnz_per_row() > 2.0 * d.nnz_per_row(),
                "audikw {:.1} vs {other} {:.1}",
                aud.nnz_per_row(),
                d.nnz_per_row()
            );
        }
    }

    #[test]
    fn scales_are_ordered() {
        let t = dataset("g3_circuit", Scale::Tiny);
        let s = dataset("g3_circuit", Scale::Small);
        assert!(s.n() > 10 * t.n());
    }
}
