//! Edge-element (Nédélec) curl-curl generator — the `Ieej`-class
//! substrate: finite edge-element discretization of
//! `∇×(ν ∇×A) = J₀` (paper eq. 5.1, the IEEJ benchmark). The curl-curl
//! operator has a large null space (gradients), so the assembled matrix is
//! symmetric *semi*-definite — which is exactly why the paper solves it
//! with the **shifted** ICCG method (σ = 0.3).
//!
//! Unknowns live on the edges of a hexahedral grid. Per cell and per axis,
//! the discrete curl of the 4 edges looping around that axis contributes a
//! rank-1 element stiffness `ν (Σ ± e)²`, mirroring the lowest-order
//! edge-element assembly (loop circulation). Each interior edge touches 4
//! cells × 3 loops ⇒ ~33 coupled edges, close to Ieej's ~31 nnz/row.

use crate::sparse::coo::Coo;
use crate::sparse::csr::Csr;
use crate::util::rng::Rng;

/// Edge index layout for an `nx × ny × nz` cell grid.
struct EdgeGrid {
    nx: usize,
    ny: usize,
    nz: usize,
    n_ex: usize,
    n_ey: usize,
}

impl EdgeGrid {
    fn new(nx: usize, ny: usize, nz: usize) -> EdgeGrid {
        let n_ex = nx * (ny + 1) * (nz + 1);
        let n_ey = (nx + 1) * ny * (nz + 1);
        EdgeGrid { nx, ny, nz, n_ex, n_ey }
    }

    fn num_edges(&self) -> usize {
        self.n_ex + self.n_ey + (self.nx + 1) * (self.ny + 1) * self.nz
    }

    /// x-directed edge at cell-offset (i, j, k): from node (i,j,k) to (i+1,j,k).
    fn ex(&self, i: usize, j: usize, k: usize) -> usize {
        (k * (self.ny + 1) + j) * self.nx + i
    }

    fn ey(&self, i: usize, j: usize, k: usize) -> usize {
        self.n_ex + (k * self.ny + j) * (self.nx + 1) + i
    }

    fn ez(&self, i: usize, j: usize, k: usize) -> usize {
        self.n_ex + self.n_ey + (k * (self.ny + 1) + j) * (self.nx + 1) + i
    }
}

/// Assemble the curl-curl operator. `nu_jump` > 0 adds log-normal
/// reluctivity variation per cell (iron/air regions); `mass_eps` adds a
/// tiny mass term keeping the matrix numerically semi-definite-plus
/// (the paper's system is singular up to gauge; CG needs `b ∈ range(A)`,
/// the small mass term plays the role of the discrete gauge here).
pub fn curl_curl3d(nx: usize, ny: usize, nz: usize, nu_jump: f64, mass_eps: f64, seed: u64) -> Csr {
    let g = EdgeGrid::new(nx, ny, nz);
    let n = g.num_edges();
    let mut rng = Rng::new(seed);
    let mut coo = Coo::with_capacity(n, 36 * n);
    let mut diag = vec![0.0f64; n];

    // For each cell, three cell-averaged curl components, each the mean of
    // the circulations of its two parallel faces — an 8-edge signed stencil
    // per component (lowest-order hex edge element, rank-3 element matrix
    // Σ_axes ν c cᵀ). Gradients circulate to zero on every face, so the
    // null space is exactly the discrete gradients.
    for k in 0..nz {
        for j in 0..ny {
            for i in 0..nx {
                let nu = if nu_jump > 0.0 { rng.log_normal(nu_jump) } else { 1.0 };
                let mut curls: [Vec<(usize, f64)>; 3] =
                    [Vec::with_capacity(8), Vec::with_capacity(8), Vec::with_capacity(8)];
                // curl_x: yz-plane faces at x = i, i+1.
                for (t, x) in [i, i + 1].into_iter().enumerate() {
                    let s = 0.5 * [1.0, 1.0][t];
                    curls[0].push((g.ey(x, j, k), s));
                    curls[0].push((g.ez(x, j + 1, k), s));
                    curls[0].push((g.ey(x, j, k + 1), -s));
                    curls[0].push((g.ez(x, j, k), -s));
                }
                // curl_y: xz-plane faces at y = j, j+1.
                for y in [j, j + 1] {
                    let s = 0.5;
                    curls[1].push((g.ez(i, y, k), s));
                    curls[1].push((g.ex(i, y, k + 1), s));
                    curls[1].push((g.ez(i + 1, y, k), -s));
                    curls[1].push((g.ex(i, y, k), -s));
                }
                // curl_z: xy-plane faces at z = k, k+1.
                for z in [k, k + 1] {
                    let s = 0.5;
                    curls[2].push((g.ex(i, j, z), s));
                    curls[2].push((g.ey(i + 1, j, z), s));
                    curls[2].push((g.ex(i, j + 1, z), -s));
                    curls[2].push((g.ey(i, j, z), -s));
                }
                for lp in &curls {
                    for (ea, sa) in lp.iter() {
                        for (eb, sb) in lp.iter() {
                            let v = nu * sa * sb;
                            coo.push(*ea, *eb, v);
                            if ea == eb {
                                diag[*ea] += v;
                            }
                        }
                    }
                }
            }
        }
    }
    // Tiny mass regularization.
    for (i, d) in diag.iter().enumerate() {
        coo.push(i, i, mass_eps * (1.0 + d));
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::factor::ic0::ic0;

    #[test]
    fn edge_counts() {
        let g = EdgeGrid::new(2, 2, 2);
        // 3 * n*(n+1)^2 for cube: 2*9*3 = 54
        assert_eq!(g.num_edges(), 54);
    }

    #[test]
    fn symmetric_and_sized_like_ieej() {
        let a = curl_curl3d(6, 6, 6, 0.0, 1e-6, 3);
        assert!(a.is_symmetric(1e-10));
        let avg = a.nnz() as f64 / a.n() as f64;
        // Interior edges couple to ~30 others (Ieej: ~31 nnz/row);
        // boundary edges fewer → average in the 15–34 band.
        assert!((15.0..34.0).contains(&avg), "avg={avg}");
    }

    #[test]
    fn curl_of_gradient_is_zero() {
        // The discrete gradient of a nodal field lies in the null space of
        // the (unregularized) operator: A · grad(φ) ≈ 0.
        let (nx, ny, nz) = (3usize, 3, 3);
        let a = curl_curl3d(nx, ny, nz, 0.0, 0.0, 1);
        let g = EdgeGrid::new(nx, ny, nz);
        // Nodal potential φ(i,j,k) = some arbitrary values.
        let nid = |i: usize, j: usize, k: usize| (k * (ny + 1) + j) * (nx + 1) + i;
        let nnodes = (nx + 1) * (ny + 1) * (nz + 1);
        let phi: Vec<f64> = (0..nnodes).map(|t| ((t * 37 % 11) as f64) * 0.3 - 1.0).collect();
        let mut e = vec![0.0f64; a.n()];
        for k in 0..=nz {
            for j in 0..=ny {
                for i in 0..nx {
                    e[g.ex(i, j, k)] = phi[nid(i + 1, j, k)] - phi[nid(i, j, k)];
                }
            }
        }
        for k in 0..=nz {
            for j in 0..ny {
                for i in 0..=nx {
                    e[g.ey(i, j, k)] = phi[nid(i, j + 1, k)] - phi[nid(i, j, k)];
                }
            }
        }
        for k in 0..nz {
            for j in 0..=ny {
                for i in 0..=nx {
                    e[g.ez(i, j, k)] = phi[nid(i, j, k + 1)] - phi[nid(i, j, k)];
                }
            }
        }
        let mut y = vec![0.0f64; a.n()];
        a.mul_vec(&e, &mut y);
        let norm: f64 = y.iter().map(|v| v * v).sum::<f64>().sqrt();
        let enorm: f64 = e.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(norm < 1e-10 * enorm.max(1.0), "A·grad φ = {norm}, not in null space");
    }

    #[test]
    fn plain_ic_breaks_down_shifted_succeeds() {
        // The semi-definite system motivates the paper's shift σ = 0.3.
        let a = curl_curl3d(4, 4, 4, 0.3, 1e-8, 5);
        // Plain IC(0) on the near-singular operator is fragile; the shifted
        // factorization must succeed.
        let shifted = ic0(&a, 0.3);
        assert!(shifted.is_ok());
    }
}
