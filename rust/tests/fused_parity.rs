//! Bitwise-parity acceptance suite for the single-dispatch CG redesign.
//!
//! Contract under test (ISSUE 4):
//!
//! * the fused single-dispatch loop reproduces the legacy per-kernel path
//!   **exactly** — identical residual histories, iteration counts and
//!   solution bits — for all five orderings (including the level-scheduled
//!   wavefront path) × threads ∈ {1, 4} × SpMV ∈ {CRS, SELL};
//! * fused results are bitwise-deterministic across runs *and across
//!   thread counts* (the chunk-grid reductions are partition-invariant);
//! * a converged solve performs **exactly one** `Pool::run` dispatch on
//!   the fused path (vs one per kernel invocation on the legacy path),
//!   and its barrier count matches the analytic sync model;
//! * the service surfaces the dispatch counter (`ServiceStats`).

use hbmc::api::SolverService;
use hbmc::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::metrics::syncs_per_fused_iteration;
use hbmc::coordinator::pool::Pool;
use hbmc::gen::suite;
use hbmc::solver::plan::{ExecOptions, SolveOutcome, SolverPlan};

const ORDERINGS: [OrderingKind; 5] = [
    OrderingKind::Natural,
    OrderingKind::Mc,
    OrderingKind::Bmc,
    OrderingKind::Hbmc,
    OrderingKind::Level,
];

fn cfg_for(ordering: OrderingKind, spmv: SpmvKind, shift: f64) -> SolverConfig {
    SolverConfig {
        ordering,
        bs: 8,
        w: 4,
        spmv,
        shift,
        rtol: 1e-6,
        threads: 1,
        ..Default::default()
    }
}

fn run(plan: &SolverPlan, b: &[f64], nt: usize, legacy: bool) -> SolveOutcome {
    let pool = Pool::new(nt);
    plan.execute(
        &pool,
        b,
        &ExecOptions { record_history: true, legacy_loop: legacy, ..Default::default() },
    )
    .expect("solve")
}

fn assert_bitwise_equal(a: &SolveOutcome, b: &SolveOutcome, what: &str) {
    assert_eq!(a.cg.iterations, b.cg.iterations, "{what}: iteration count");
    assert_eq!(a.cg.converged, b.cg.converged, "{what}: converged flag");
    assert_eq!(
        a.cg.final_relres.to_bits(),
        b.cg.final_relres.to_bits(),
        "{what}: final relres"
    );
    assert_eq!(
        a.cg.residual_history.len(),
        b.cg.residual_history.len(),
        "{what}: history length"
    );
    for (i, (ra, rb)) in a
        .cg
        .residual_history
        .iter()
        .zip(&b.cg.residual_history)
        .enumerate()
    {
        assert_eq!(ra.to_bits(), rb.to_bits(), "{what}: history[{i}]");
    }
    assert_eq!(a.x.len(), b.x.len());
    for (i, (xa, xb)) in a.x.iter().zip(&b.x).enumerate() {
        assert_eq!(xa.to_bits(), xb.to_bits(), "{what}: x[{i}]");
    }
}

/// The headline matrix: fused ≡ legacy, bit for bit, across the full
/// orderings × threads × SpMV grid.
#[test]
fn fused_loop_is_bitwise_identical_to_legacy_everywhere() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    for ordering in ORDERINGS {
        for spmv in [SpmvKind::Crs, SpmvKind::Sell] {
            let cfg = cfg_for(ordering, spmv, d.shift);
            let plan = SolverPlan::build(&d.matrix, &cfg).expect("plan");
            let legacy1 = run(&plan, &d.b, 1, true);
            assert!(
                legacy1.cg.converged,
                "{ordering:?}/{spmv:?} must converge (relres={})",
                legacy1.cg.final_relres
            );
            for nt in [1usize, 4] {
                let fused = run(&plan, &d.b, nt, false);
                assert_bitwise_equal(&fused, &legacy1, &format!("{ordering:?}/{spmv:?} nt={nt}"));
                let legacy = run(&plan, &d.b, nt, true);
                assert_bitwise_equal(&legacy, &legacy1, &format!("legacy {ordering:?} nt={nt}"));
            }
        }
    }
}

/// Run-to-run and cross-thread-count bitwise determinism of the fused
/// path, asserted directly (not just via transitivity through legacy).
#[test]
fn fused_loop_is_deterministic_across_runs_and_thread_counts() {
    let d = suite::dataset("thermal2", Scale::Tiny);
    let cfg = cfg_for(OrderingKind::Hbmc, SpmvKind::Sell, d.shift);
    let plan = SolverPlan::build(&d.matrix, &cfg).expect("plan");
    let reference = run(&plan, &d.b, 1, false);
    assert!(reference.cg.converged);
    for nt in [1usize, 2, 4] {
        for rep in 0..2 {
            let again = run(&plan, &d.b, nt, false);
            assert_bitwise_equal(&again, &reference, &format!("nt={nt} rep={rep}"));
        }
    }
}

/// A converged fused solve is exactly one pool dispatch; the legacy loop
/// pays one dispatch per kernel invocation (3 per iteration + 3 for the
/// initialization on the parallel orderings). Barrier counts match the
/// analytic model in `coordinator::metrics`.
#[test]
fn fused_solve_is_exactly_one_dispatch_with_modeled_syncs() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    for ordering in ORDERINGS {
        for spmv in [SpmvKind::Crs, SpmvKind::Sell] {
            let cfg = cfg_for(ordering, spmv, d.shift);
            let plan = SolverPlan::build(&d.matrix, &cfg).expect("plan");
            for nt in [1usize, 4] {
                let fused = run(&plan, &d.b, nt, false);
                assert!(fused.cg.converged);
                assert_eq!(
                    fused.dispatches, 1,
                    "{ordering:?}/{spmv:?} nt={nt}: fused solve must be one dispatch"
                );

                // Sync accounting: init (one barrier more than a steady
                // iteration — the post-combine fence) + (k−1) full
                // iterations + the converged iteration's two (CRS) or
                // three (SELL) phases.
                let nc = plan.trisolver.num_colors();
                let sell = matches!(spmv, SpmvKind::Sell);
                let k = fused.cg.iterations;
                assert!(k >= 1);
                let init = 2 * (nc - 1) + 7;
                let expected =
                    init + (k - 1) * syncs_per_fused_iteration(nc, sell) + 2 + usize::from(sell);
                assert_eq!(
                    fused.pool_syncs as usize, expected,
                    "{ordering:?}/{spmv:?} nt={nt}: sync accounting drifted"
                );

                let legacy = run(&plan, &d.b, nt, true);
                assert!(
                    legacy.dispatches > fused.dispatches,
                    "{ordering:?}/{spmv:?}: legacy must dispatch more"
                );
                if ordering != OrderingKind::Natural {
                    // Init pays SpMV + forward + backward (3); each full
                    // iteration pays the same trio; the converged final
                    // iteration stops after its SpMV.
                    assert_eq!(legacy.dispatches as usize, 3 * legacy.cg.iterations + 1);
                } else {
                    // Natural ordering substitutes serially on the caller:
                    // only SpMV dispatches (init + one per iteration).
                    assert_eq!(legacy.dispatches as usize, legacy.cg.iterations + 1);
                }
            }
        }
    }
}

/// The level-scheduled path keeps the natural (identity) ordering, so on
/// every suite matrix it must reproduce the serial natural-ordering solve
/// **bitwise** — same iteration count, same residual history, same
/// solution — at every thread count, in a single dispatch. This is the
/// scheduling path's headline property: wavefront parallelism with zero
/// convergence penalty.
#[test]
fn level_path_matches_natural_ordering_iterations_exactly() {
    for name in suite::NAMES {
        let d = suite::dataset(name, Scale::Tiny);
        let natural_plan =
            SolverPlan::build(&d.matrix, &cfg_for(OrderingKind::Natural, SpmvKind::Crs, d.shift))
                .expect("natural plan");
        let natural = run(&natural_plan, &d.b, 1, false);
        assert!(
            natural.cg.converged,
            "{name}: natural baseline must converge (relres={})",
            natural.cg.final_relres
        );

        let plan =
            SolverPlan::build(&d.matrix, &cfg_for(OrderingKind::Level, SpmvKind::Crs, d.shift))
                .expect("level plan");
        for nt in [1usize, 2, 4] {
            let level = run(&plan, &d.b, nt, false);
            assert_eq!(
                level.cg.iterations, natural.cg.iterations,
                "{name} nt={nt}: level path must not change the ICCG iteration count"
            );
            assert_bitwise_equal(&level, &natural, &format!("{name} level nt={nt}"));
            assert_eq!(level.dispatches, 1, "{name} nt={nt}: level path is one dispatch");
        }
    }
}

/// The service's stats surface the dispatch counter: with the fused loop,
/// dispatches == solves.
#[test]
fn service_stats_count_one_dispatch_per_solve() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let cfg = cfg_for(OrderingKind::Hbmc, SpmvKind::Sell, d.shift);
    let service = SolverService::with_config(cfg).expect("service");
    let handle = service.register_matrix(d.matrix.clone());
    for scale in [1.0f64, 2.0, -0.5] {
        let b: Vec<f64> = d.b.iter().map(|v| v * scale).collect();
        let out = service.solve(handle, &b).expect("solve");
        assert!(out.report.converged);
        assert_eq!(out.report.dispatches, 1);
    }
    let st = service.stats();
    assert_eq!(st.solves, 3);
    assert_eq!(st.dispatches, st.solves, "fused serving: one dispatch per solve");
}
