//! Full-solver integration: every ordering × SpMV × thread-count
//! combination solves the suite correctly; shifted ICCG handles the
//! semi-definite Ieej-class system; configuration knobs behave.

use hbmc::config::{NodePreset, OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::driver::{solve, solve_opts, SolveOptions};
use hbmc::gen::suite;
use hbmc::solver::iccg::IccgSolver;

fn unit_err(solution: &[f64]) -> f64 {
    solution.iter().map(|x| (x - 1.0).abs()).fold(0.0, f64::max)
}

#[test]
fn full_matrix_of_configurations_on_g3() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    for ordering in [OrderingKind::Natural, OrderingKind::Mc, OrderingKind::Bmc, OrderingKind::Hbmc] {
        for spmv in [SpmvKind::Crs, SpmvKind::Sell] {
            for threads in [1usize, 2] {
                let cfg = SolverConfig {
                    ordering,
                    spmv,
                    threads,
                    bs: 8,
                    w: 4,
                    rtol: 1e-7,
                    ..Default::default()
                };
                let rep =
                    solve_opts(&d.matrix, &d.b, &cfg, &SolveOptions::with_solution()).unwrap();
                assert!(
                    rep.converged,
                    "{ordering:?}/{spmv:?}/t{threads} relres={}",
                    rep.final_relres
                );
                let sol = rep.solution.as_ref().unwrap();
                assert!(
                    unit_err(sol) < 1e-4,
                    "{ordering:?}/{spmv:?}/t{threads} err={}",
                    unit_err(sol)
                );
            }
        }
    }
}

#[test]
fn iteration_count_invariant_under_threads_and_spmv() {
    let d = suite::dataset("thermal2", Scale::Tiny);
    let mut iters = Vec::new();
    for threads in [1usize, 2, 4] {
        for spmv in [SpmvKind::Crs, SpmvKind::Sell] {
            let cfg = SolverConfig {
                ordering: OrderingKind::Hbmc,
                bs: 8,
                w: 4,
                threads,
                spmv,
                rtol: 1e-7,
                ..Default::default()
            };
            iters.push(solve(&d.matrix, &d.b, &cfg).unwrap().iterations);
        }
    }
    let first = iters[0];
    assert!(
        iters.iter().all(|&i| i.abs_diff(first) <= 1),
        "iterations vary: {iters:?}"
    );
}

#[test]
fn shifted_iccg_solves_ieej_class() {
    // The paper's protocol: shift σ = 0.3 for the eddy-current system.
    let d = suite::dataset("ieej", Scale::Tiny);
    assert_eq!(d.shift, 0.3);
    let cfg = SolverConfig {
        ordering: OrderingKind::Hbmc,
        bs: 16,
        w: 8,
        shift: d.shift,
        rtol: 1e-7,
        ..Default::default()
    };
    let rep = solve_opts(&d.matrix, &d.b, &cfg, &SolveOptions::with_solution()).unwrap();
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert!(rep.plan.setup.shift_used >= 0.3);
    assert!(unit_err(rep.solution.as_ref().unwrap()) < 1e-3);
}

#[test]
fn all_five_datasets_solve_with_paper_defaults() {
    for d in suite::all(Scale::Tiny) {
        let cfg = SolverConfig {
            ordering: OrderingKind::Hbmc,
            bs: 32,
            w: 8,
            spmv: SpmvKind::Sell,
            shift: d.shift,
            rtol: 1e-7,
            ..Default::default()
        };
        let rep = solve(&d.matrix, &d.b, &cfg).unwrap();
        assert!(rep.converged, "{} relres={}", d.name, rep.final_relres);
        println!(
            "{:<14} n={:>6} iters={:>5} simd={:>5.1}% sell_ovh={:+.1}%",
            d.name,
            d.n(),
            rep.iterations,
            100.0 * rep.plan.simd_ratio,
            100.0 * (rep.plan.sell_overhead.unwrap() - 1.0)
        );
    }
}

#[test]
fn intrinsic_and_scalar_paths_agree() {
    let d = suite::dataset("audikw_1", Scale::Tiny);
    let mk = |use_intrinsics| SolverConfig {
        ordering: OrderingKind::Hbmc,
        bs: 8,
        w: 8,
        use_intrinsics,
        rtol: 1e-8,
        ..Default::default()
    };
    let a = solve_opts(&d.matrix, &d.b, &mk(true), &SolveOptions::with_solution()).unwrap();
    let b = solve_opts(&d.matrix, &d.b, &mk(false), &SolveOptions::with_solution()).unwrap();
    assert_eq!(a.iterations, b.iterations);
    let max_dev = a
        .solution
        .as_ref()
        .unwrap()
        .iter()
        .zip(b.solution.as_ref().unwrap())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max);
    assert!(max_dev < 1e-9, "intrinsic vs scalar deviate: {max_dev}");
}

#[test]
fn node_presets_solve() {
    let d = suite::dataset("parabolic_fem", Scale::Tiny);
    for node in NodePreset::all() {
        let mut cfg = SolverConfig { ordering: OrderingKind::Hbmc, bs: 16, ..Default::default() };
        node.apply(&mut cfg);
        let rep = solve(&d.matrix, &d.b, &cfg).unwrap();
        assert!(rep.converged, "{:?}", node);
        assert_eq!(cfg.w, node.w());
    }
}

#[test]
fn sell_sigma_variant_matches_unsorted() {
    let d = suite::dataset("audikw_1", Scale::Tiny);
    let mk = |sigma| SolverConfig {
        ordering: OrderingKind::Hbmc,
        bs: 8,
        w: 8,
        spmv: SpmvKind::Sell,
        sell_sigma: sigma,
        rtol: 1e-7,
        ..Default::default()
    };
    let plain = IccgSolver::new(&d.matrix, &mk(None)).unwrap();
    let sorted = IccgSolver::new(&d.matrix, &mk(Some(64))).unwrap();
    // σ-sorting strictly reduces stored elements on the imbalanced set.
    assert!(sorted.setup().spmv_elements < plain.setup().spmv_elements);
    let op = plain.solve(&d.b).unwrap();
    let os = sorted.solve(&d.b).unwrap();
    assert_eq!(op.cg.iterations, os.cg.iterations);
}

#[test]
fn solver_is_reusable_across_rhs() {
    let d = suite::dataset("thermal2", Scale::Tiny);
    let solver = IccgSolver::new(&d.matrix, &SolverConfig {
        ordering: OrderingKind::Hbmc,
        bs: 8,
        w: 4,
        rtol: 1e-8,
        ..Default::default()
    })
    .unwrap();
    let o1 = solver.solve(&d.b).unwrap();
    // Second rhs: 2·b → solution 2·1.
    let b2: Vec<f64> = d.b.iter().map(|v| 2.0 * v).collect();
    let o2 = solver.solve(&b2).unwrap();
    assert!(o1.cg.converged && o2.cg.converged);
    let err = o2.x.iter().map(|x| (x - 2.0).abs()).fold(0.0, f64::max);
    assert!(err < 1e-4, "err={err}");
}
