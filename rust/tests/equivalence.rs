//! End-to-end equivalence tests — the paper's central claim (§4.2.1,
//! Table 5.2, Fig. 5.1): HBMC and BMC are equivalent orderings, so the
//! ICCG iteration counts and residual histories coincide; MC converges
//! more slowly.

use hbmc::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::driver::{solve_opts, SolveOptions};
use hbmc::gen::suite;
use hbmc::ordering::graph::{er_condition_holds, orderings_equivalent, Adjacency};
use hbmc::ordering::hbmc::{check_level2_diagonal, hbmc_order};
use hbmc::ordering::perm::Perm;

fn cfg(ordering: OrderingKind, bs: usize, w: usize) -> SolverConfig {
    SolverConfig {
        ordering,
        bs,
        w,
        spmv: SpmvKind::Crs,
        rtol: 1e-7,
        max_iters: 20_000,
        ..Default::default()
    }
}

#[test]
fn bmc_hbmc_iteration_exact_on_all_datasets() {
    // Table 5.2's BMC == HBMC columns, all five datasets.
    for d in suite::all(Scale::Tiny) {
        let mut cb = cfg(OrderingKind::Bmc, 16, 4);
        cb.shift = d.shift;
        let mut ch = cfg(OrderingKind::Hbmc, 16, 4);
        ch.shift = d.shift;
        let rb = solve_opts(&d.matrix, &d.b, &cb, &SolveOptions::history()).unwrap();
        let rh = solve_opts(&d.matrix, &d.b, &ch, &SolveOptions::history()).unwrap();
        assert!(rb.converged && rh.converged, "{}", d.name);
        // Equivalence is exact in exact arithmetic; in FP the reassociated
        // kernels drift at round-off level, which ill-conditioned systems
        // (ieej: semi-definite curl-curl) amplify over hundreds of
        // iterations — the paper's own Table 5.2 shows Audikw_1 at 1714 vs
        // 1715. Allow 1% in the count, and require the curves to overlap
        // tightly in the early (pre-amplification) phase.
        let tol_iters = 2 + rb.iterations / 20;
        assert!(
            rb.iterations.abs_diff(rh.iterations) <= tol_iters,
            "{}: BMC {} vs HBMC {}",
            d.name,
            rb.iterations,
            rh.iterations
        );
        for (i, (a, b)) in rb
            .residual_history
            .iter()
            .zip(&rh.residual_history)
            .take(20)
            .enumerate()
        {
            assert!(
                (a - b).abs() <= 1e-4 * a.max(*b).max(1e-30),
                "{} iter {i}: {a} vs {b}",
                d.name
            );
        }
    }
}

#[test]
fn equivalence_holds_across_block_sizes_and_widths() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    for (bs, w) in [(8usize, 4usize), (16, 8), (32, 8)] {
        let rb = solve_opts(&d.matrix, &d.b, &cfg(OrderingKind::Bmc, bs, w), &SolveOptions::default()).unwrap();
        let rh = solve_opts(&d.matrix, &d.b, &cfg(OrderingKind::Hbmc, bs, w), &SolveOptions::default()).unwrap();
        assert!(
            rb.iterations.abs_diff(rh.iterations) <= 1 + rb.iterations / 100,
            "bs={bs} w={w}: {} vs {}",
            rb.iterations,
            rh.iterations
        );
    }
}

#[test]
fn ordering_graphs_identical_on_all_datasets() {
    // The structural form of the theorem, on every generator.
    for d in suite::all(Scale::Tiny) {
        let ord = hbmc_order(&d.matrix, 8, 4);
        assert!(
            orderings_equivalent(&d.matrix, &ord.bmc.perm, &ord.perm),
            "{}: ordering graphs differ",
            d.name
        );
        let b = d.matrix.permute_sym(&ord.perm);
        assert_eq!(check_level2_diagonal(&b, &ord), None, "{}", d.name);
        // The reordered system in its own (identity) order satisfies ER.
        assert!(er_condition_holds(&b, &Perm::identity(b.n())));
    }
}

#[test]
fn bmc_converges_no_worse_than_mc_in_majority() {
    // Table 5.2's MC-vs-BMC trend ([13]'s result): block coloring improves
    // convergence on most datasets.
    let mut wins = 0;
    let mut total = 0;
    for d in suite::all(Scale::Tiny) {
        let mut cm = cfg(OrderingKind::Mc, 32, 4);
        cm.shift = d.shift;
        let mut cb = cfg(OrderingKind::Bmc, 32, 4);
        cb.shift = d.shift;
        let rm = solve_opts(&d.matrix, &d.b, &cm, &SolveOptions::default()).unwrap();
        let rb = solve_opts(&d.matrix, &d.b, &cb, &SolveOptions::default()).unwrap();
        assert!(rm.converged && rb.converged, "{}", d.name);
        total += 1;
        if rb.iterations <= rm.iterations {
            wins += 1;
        }
        println!("{}: MC={} BMC={}", d.name, rm.iterations, rb.iterations);
    }
    assert!(wins * 2 > total, "BMC should beat MC on a majority: {wins}/{total}");
}

#[test]
fn hbmc_uses_fewer_colors_than_mc() {
    // Block coloring coarsens the conflict graph: far fewer colors than
    // nodal MC on stencil-ish problems ⇒ fewer synchronizations.
    for name in ["thermal2", "g3_circuit"] {
        let d = suite::dataset(name, Scale::Tiny);
        let adj = Adjacency::from_csr(&d.matrix);
        let mc = hbmc::ordering::mc::mc_order(&d.matrix);
        let ord = hbmc_order(&d.matrix, 16, 4);
        println!(
            "{name}: mc_colors={} hbmc_colors={} maxdeg={}",
            mc.num_colors,
            ord.num_colors,
            adj.max_degree()
        );
        // Same sync count as BMC by construction.
        assert_eq!(ord.num_colors, ord.bmc.num_colors);
    }
}

#[test]
fn natural_serial_is_the_convergence_reference() {
    // IC in natural ordering typically converges fastest (no parallel
    // ordering penalty); MC/BMC/HBMC pay a bounded penalty.
    let d = suite::dataset("parabolic_fem", Scale::Tiny);
    let rn = solve_opts(&d.matrix, &d.b, &cfg(OrderingKind::Natural, 1, 1), &SolveOptions::default()).unwrap();
    let rh = solve_opts(&d.matrix, &d.b, &cfg(OrderingKind::Hbmc, 16, 4), &SolveOptions::default()).unwrap();
    assert!(rn.converged && rh.converged);
    // Sanity bound: parallel ordering costs at most 4x iterations here.
    assert!(rh.iterations <= 4 * rn.iterations.max(1));
}
