//! End-to-end tests of the autotuner subsystem: profile-store durability
//! (round-trip property, corrupt files, stale schemas), the tuner's
//! never-worse-than-default guarantee, service auto-application with
//! `ServiceStats::profile_hits`, and fused/legacy parity under tuned
//! configurations.

use std::path::PathBuf;
use std::sync::Arc;

use hbmc::api::{HbmcError, SolveRequest, SolverService};
use hbmc::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::pool::Pool;
use hbmc::gen::suite;
use hbmc::solver::plan::{ExecOptions, SolverPlan};
use hbmc::tune::{
    tune_matrix, ConfigSpace, HardwareSignature, ProfileStore, SimdLevel, TuneOptions,
    TunedProfile, TuneStrategy,
};
use hbmc::util::rng::Rng;

/// Unique scratch path under the OS temp dir (no tempfile crate offline;
/// each test owns a distinct file name and removes it on exit).
fn scratch(name: &str) -> PathBuf {
    let mut p = std::env::temp_dir();
    p.push(format!("hbmc_tune_test_{}_{name}", std::process::id()));
    p
}

fn small_space() -> ConfigSpace {
    ConfigSpace {
        orderings: vec![OrderingKind::Bmc, OrderingKind::Hbmc],
        block_sizes: vec![8],
        widths: vec![4],
        spmvs: vec![SpmvKind::Crs, SpmvKind::Sell],
        sigma_slices: vec![None],
        threads: vec![1],
    }
}

fn tiny_base() -> SolverConfig {
    SolverConfig { ordering: OrderingKind::Hbmc, bs: 8, w: 4, rtol: 1e-7, ..Default::default() }
}

fn random_profile(rng: &mut Rng) -> TunedProfile {
    let orderings = [
        OrderingKind::Natural,
        OrderingKind::Mc,
        OrderingKind::Bmc,
        OrderingKind::Hbmc,
        OrderingKind::Level,
    ];
    let simds = [SimdLevel::Scalar, SimdLevel::Avx2, SimdLevel::Avx512];
    let w = [1usize, 2, 4, 8][rng.below(4)];
    let bs = w * (1 + rng.below(8));
    TunedProfile {
        fingerprint: rng.next_u64(),
        hardware: HardwareSignature { simd: simds[rng.below(3)], cores: 1 + rng.below(64) },
        ordering: orderings[rng.below(5)],
        bs,
        w,
        spmv: if rng.below(2) == 0 { SpmvKind::Crs } else { SpmvKind::Sell },
        sell_sigma: if rng.below(2) == 0 { None } else { Some(w * (1 + rng.below(32))) },
        threads: 1 + rng.below(16),
        use_intrinsics: rng.below(2) == 0,
        solve_seconds: rng.range_f64(1e-6, 10.0),
        setup_seconds: rng.range_f64(1e-6, 100.0),
        iterations: rng.below(10_000),
        baseline_solve_seconds: rng.range_f64(1e-6, 10.0),
        phase_shares: if rng.below(2) == 0 {
            None
        } else {
            Some(std::array::from_fn(|_| rng.range_f64(0.0, 1.0)))
        },
        created_unix: rng.next_u64() >> 20, // keep within f64-exact range
    }
}

#[test]
fn profile_store_round_trip_property() {
    // 64 randomized profiles (deterministic seed): serialize the store,
    // parse it back, and require field-exact equality — including
    // fingerprints above 2^53, which a naive JSON number would corrupt.
    let mut rng = Rng::new(0xc0ffee);
    let mut store = ProfileStore::in_memory();
    let mut expected = Vec::new();
    for _ in 0..64 {
        let p = random_profile(&mut rng);
        store.put(p.clone());
        expected.retain(|q: &TunedProfile| q.key() != p.key());
        expected.push(p);
    }
    let parsed = ProfileStore::parse_document(&store.to_json_text()).unwrap();
    assert_eq!(parsed.len(), expected.len());
    for p in &expected {
        assert!(parsed.contains(p), "lost or mangled profile {p:?}");
    }
}

#[test]
fn profile_store_file_round_trip() {
    let path = scratch("roundtrip.json");
    let _ = std::fs::remove_file(&path);
    let mut rng = Rng::new(42);
    let p = random_profile(&mut rng);
    {
        let mut store = ProfileStore::open(&path).unwrap();
        assert!(store.is_empty(), "missing file must read as empty");
        store.put(p.clone());
        store.save().unwrap();
    }
    let reloaded = ProfileStore::open(&path).unwrap();
    assert_eq!(reloaded.len(), 1);
    assert_eq!(reloaded.get(&p.key()), Some(&p));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn corrupt_or_truncated_store_is_parse_error_never_panic() {
    let path = scratch("corrupt.json");
    let full = {
        let mut store = ProfileStore::in_memory();
        store.put(random_profile(&mut Rng::new(7)));
        store.to_json_text()
    };
    // A truncated prefix of a real store, plus assorted garbage.
    let cases: Vec<String> = vec![
        full[..full.len() / 2].to_string(),
        "not json at all".into(),
        "{\"schema_version\": \"one\"}".into(),
        "{\"schema_version\": 1, \"profiles\": [{\"fingerprint\": 12}]}".into(),
        "\u{0}\u{1}\u{2}".into(),
    ];
    for text in cases {
        std::fs::write(&path, &text).unwrap();
        let err = ProfileStore::open(&path).unwrap_err();
        assert!(matches!(err, HbmcError::Parse(_)), "{text:?} -> {err:?}");
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn stale_schema_version_is_ignored_and_rebuilt() {
    let path = scratch("stale.json");
    std::fs::write(
        &path,
        "{\"schema_version\": 9999, \"profiles\": [{\"whatever\": \"format\"}]}",
    )
    .unwrap();
    let mut store = ProfileStore::open(&path).unwrap();
    assert!(store.is_empty(), "stale-schema profiles must be dropped, not parsed");
    // The rebuild path: put + save rewrites the file at the current schema.
    let p = random_profile(&mut Rng::new(9));
    store.put(p.clone());
    store.save().unwrap();
    let reloaded = ProfileStore::open(&path).unwrap();
    assert_eq!(reloaded.get(&p.key()), Some(&p));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn tune_never_returns_worse_than_default_time_per_solve() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let opts = TuneOptions {
        space: Some(small_space()),
        trials: 2,
        // ∞ reuse ⇒ the score IS time/solve, so the acceptance bound
        // "tuned time/solve ≤ default's" holds exactly, not just in
        // expectation: the default is always a finalist.
        expected_reuse: f64::INFINITY,
        ..Default::default()
    };
    let out = tune_matrix(&d.matrix, &d.b, &tiny_base(), &opts).unwrap();
    assert!(out.winner.converged);
    assert!(out.profile.solve_seconds <= out.profile.baseline_solve_seconds);
    assert_eq!(out.profile.fingerprint, d.matrix.fingerprint());
    assert_eq!(out.profile.hardware, HardwareSignature::detect());
}

#[test]
fn racing_strategy_handles_a_wide_space() {
    let d = suite::dataset("thermal2", Scale::Tiny);
    let opts = TuneOptions {
        space: Some(ConfigSpace {
            orderings: vec![OrderingKind::Mc, OrderingKind::Bmc, OrderingKind::Hbmc],
            block_sizes: vec![8, 16],
            widths: vec![4],
            spmvs: vec![SpmvKind::Crs, SpmvKind::Sell],
            sigma_slices: vec![None, Some(16)],
            threads: vec![1],
        }),
        strategy: TuneStrategy::Racing,
        trials: 2,
        finalists: 3,
        ..Default::default()
    };
    let out = tune_matrix(&d.matrix, &d.b, &tiny_base(), &opts).unwrap();
    assert!(out.winner.converged);
    assert!(out.candidates > opts.finalists, "space must be wider than the finalist pool");
    assert!(out.finalists.len() <= opts.finalists + 1);
    assert!(out.winner.score(opts.expected_reuse) <= out.baseline.score(opts.expected_reuse));
}

#[test]
fn service_tune_persists_and_next_service_auto_applies() {
    let path = scratch("service_store.json");
    let _ = std::fs::remove_file(&path);
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let opts = TuneOptions {
        space: Some(small_space()),
        trials: 1,
        expected_reuse: f64::INFINITY,
        ..Default::default()
    };

    // Service #1: tune and persist.
    let svc = SolverService::with_config(tiny_base()).unwrap();
    svc.attach_profile_store(&path).unwrap();
    let h = svc.register_matrix(d.matrix.clone());
    let profile = svc.tune(h, &opts).unwrap();
    let st = svc.stats();
    assert_eq!((st.tunes, st.profiles), (1, 1));
    assert_eq!(svc.profile(h).unwrap().as_ref(), Some(&profile));
    // The tune itself bypasses the queue: no profile hits yet.
    assert_eq!(st.profile_hits, 0);
    // A default-config solve on the tuning service already auto-applies.
    let tuned_out = svc.solve(h, &d.b).unwrap();
    assert!(tuned_out.report.converged);
    assert_eq!(svc.stats().profile_hits, 1);
    drop(svc);

    // Service #2 (a "new process"): the profile survives the store
    // round-trip and is auto-applied on the very next solve.
    let svc2 = SolverService::with_config(tiny_base()).unwrap();
    let installed = svc2.attach_profile_store(&path).unwrap();
    assert_eq!(installed, 1, "persisted profile must load on this machine");
    let h2 = svc2.register_matrix(d.matrix.clone());
    let stored = svc2.profile(h2).unwrap().expect("profile for the same matrix");
    assert_eq!(stored.key(), profile.key());
    assert_eq!(stored.label(), profile.label());
    let out = svc2.solve(h2, &d.b).unwrap();
    assert!(out.report.converged);
    let s2 = svc2.stats();
    assert_eq!(s2.profile_hits, 1, "auto-application must be visible in ServiceStats");
    assert_eq!(
        out.report.plan.config_label,
        profile.apply_to(&tiny_base()).label(),
        "the solve must have run under the tuned config"
    );
    // Batch solves count one hit per rhs.
    let outs = svc2.solve_many(h2, &[d.b.clone(), d.b.clone()]).unwrap();
    assert_eq!(outs.len(), 2);
    assert_eq!(svc2.stats().profile_hits, 3);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn level_profile_auto_applies_end_to_end() {
    // The level-scheduled path is a first-class tuner citizen: a profile
    // naming it persists, auto-applies on the next default-config solve,
    // and the served plan really runs the level trisolver. The space (and
    // the incumbent) are pinned to Level so the winner's ordering is
    // deterministic regardless of timing noise.
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let opts = TuneOptions {
        space: Some(ConfigSpace {
            orderings: vec![OrderingKind::Level],
            block_sizes: vec![8],
            widths: vec![4],
            spmvs: vec![SpmvKind::Crs],
            sigma_slices: vec![None],
            threads: vec![1],
        }),
        trials: 1,
        expected_reuse: f64::INFINITY,
        ..Default::default()
    };
    let base = SolverConfig {
        ordering: OrderingKind::Level,
        spmv: SpmvKind::Crs,
        rtol: 1e-7,
        ..Default::default()
    };
    let svc = SolverService::with_config(base).unwrap();
    let h = svc.register_matrix(d.matrix.clone());
    let profile = svc.tune(h, &opts).unwrap();
    assert_eq!(profile.ordering, OrderingKind::Level);
    let out = svc.solve(h, &d.b).unwrap();
    assert!(out.report.converged);
    assert_eq!(out.report.plan.trisolver, "ic0-level");
    assert!(
        out.report.plan.schedule.is_some(),
        "the auto-applied level plan must surface its schedule cost model"
    );
    assert!(svc.stats().profile_hits >= 1);
}

#[test]
fn tuned_config_keeps_fused_legacy_parity() {
    // Determinism must survive tuning: whatever configuration the search
    // picks, the fused single-dispatch loop and the legacy per-kernel
    // loop stay bitwise-identical on it.
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let opts = TuneOptions { space: Some(small_space()), trials: 1, ..Default::default() };
    let out = tune_matrix(&d.matrix, &d.b, &tiny_base(), &opts).unwrap();
    let cfg = out.profile.apply_to(&tiny_base());
    let plan = Arc::new(SolverPlan::build(&d.matrix, &cfg).unwrap());
    let pool = Pool::new(cfg.threads);
    let fused = plan.execute(&pool, &d.b, &ExecOptions::default()).unwrap();
    let legacy = plan
        .execute(&pool, &d.b, &ExecOptions { legacy_loop: true, ..Default::default() })
        .unwrap();
    assert_eq!(fused.cg.iterations, legacy.cg.iterations);
    assert_eq!(fused.x, legacy.x, "tuned config broke fused/legacy parity");
    // And run-to-run determinism under the tuned config.
    let again = plan.execute(&pool, &d.b, &ExecOptions::default()).unwrap();
    assert_eq!(fused.x, again.x);
}

#[test]
fn solve_request_opt_out_still_solves_with_default() {
    let d = suite::dataset("thermal2", Scale::Tiny);
    let svc = SolverService::with_config(tiny_base()).unwrap();
    let h = svc.register_matrix(d.matrix.clone());
    let opts = TuneOptions {
        space: Some(small_space()),
        trials: 1,
        expected_reuse: f64::INFINITY,
        ..Default::default()
    };
    svc.tune(h, &opts).unwrap();
    let opted_out = svc.solve_with(h, &d.b, &SolveRequest::new().no_profile()).unwrap();
    assert!(opted_out.report.converged);
    assert_eq!(
        opted_out.report.plan.config_label,
        tiny_base().label(),
        "opt-out must run the service default"
    );
    assert_eq!(svc.stats().profile_hits, 0);
}
