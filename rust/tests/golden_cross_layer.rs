//! Cross-layer golden tests: the python build path (`compile/ordering.py`,
//! `compile/kernels/ref.py`) and this crate must implement the *same*
//! deterministic algorithms. `make artifacts` bakes the python results into
//! `artifacts/golden.txt`; these tests re-derive everything in rust and
//! compare node-for-node / bit-for-bit(ish).
//!
//! Skipped (with a loud message) when artifacts are absent — run
//! `make artifacts` first.

use hbmc::config::{OrderingKind, SolverConfig, SpmvKind};
use hbmc::ordering::hbmc::hbmc_order;
use hbmc::runtime::artifacts::{canonical_matrix, ArtifactSet};
use hbmc::solver::iccg::IccgSolver;

fn artifacts() -> Option<ArtifactSet> {
    match ArtifactSet::locate() {
        Ok(a) => Some(a),
        Err(e) => {
            eprintln!("SKIP golden tests: {e:#}");
            None
        }
    }
}

#[test]
fn rust_and_python_hbmc_permutations_agree() {
    let Some(arts) = artifacts() else { return };
    let golden = arts.golden().unwrap();
    let a = canonical_matrix(&golden).unwrap();
    let bs = golden.usize("bs").unwrap();
    let w = golden.usize("w").unwrap();
    let ord = hbmc_order(&a, bs, w);

    let py_perm = golden.usize_vec("hbmc_new_of_old").unwrap();
    assert_eq!(ord.perm.n_old(), py_perm.len());
    for (i, &p) in py_perm.iter().enumerate() {
        assert_eq!(
            ord.perm.new_of_old(i),
            p,
            "node {i}: rust {} vs python {p}",
            ord.perm.new_of_old(i)
        );
    }

    let py_bmc = golden.usize_vec("bmc_new_of_old").unwrap();
    for (i, &p) in py_bmc.iter().enumerate() {
        assert_eq!(ord.bmc.perm.new_of_old(i), p, "bmc node {i}");
    }
    assert_eq!(ord.num_colors, golden.usize("num_colors").unwrap());
    assert_eq!(
        ord.color_ptr,
        golden.usize_vec("color_ptr").unwrap(),
        "color layout differs"
    );
}

#[test]
fn rust_ic0_matches_python_factor() {
    let Some(arts) = artifacts() else { return };
    let golden = arts.golden().unwrap();
    let a = canonical_matrix(&golden).unwrap();
    let bs = golden.usize("bs").unwrap();
    let w = golden.usize("w").unwrap();
    let ord = hbmc_order(&a, bs, w);
    let b = a.permute_sym(&ord.perm);
    let f = hbmc::factor::ic0::ic0(&b, 0.0).unwrap();
    let py_diag = golden.f64_vec("factor_diag").unwrap();
    assert_eq!(f.diag.len(), py_diag.len());
    let dev = hbmc::util::max_abs_diff(&f.diag, &py_diag);
    assert!(dev < 1e-12, "factor diagonals deviate: {dev}");
}

#[test]
fn rust_preconditioner_reproduces_python_golden_vector() {
    let Some(arts) = artifacts() else { return };
    let golden = arts.golden().unwrap();
    let a = canonical_matrix(&golden).unwrap();
    let bs = golden.usize("bs").unwrap();
    let w = golden.usize("w").unwrap();
    let cfg = SolverConfig {
        ordering: OrderingKind::Hbmc,
        bs,
        w,
        spmv: SpmvKind::Sell,
        ..Default::default()
    };
    let solver = IccgSolver::new(&a, &cfg).unwrap();
    let r = golden.f64_vec("precond_r").unwrap();
    let z_expect = golden.f64_vec("precond_z").unwrap();
    assert_eq!(solver.n_aug(), r.len(), "augmented dimensions differ");
    let mut z = vec![0.0; r.len()];
    solver.apply_precond_internal(&r, &mut z);
    let dev = hbmc::util::max_abs_diff(&z, &z_expect);
    assert!(dev < 1e-11, "rust preconditioner deviates from python: {dev}");
}

#[test]
fn rust_spmv_reproduces_python_golden_vector() {
    let Some(arts) = artifacts() else { return };
    let golden = arts.golden().unwrap();
    let a = canonical_matrix(&golden).unwrap();
    let bs = golden.usize("bs").unwrap();
    let w = golden.usize("w").unwrap();
    let ord = hbmc_order(&a, bs, w);
    let b = a.permute_sym(&ord.perm);
    let x = golden.f64_vec("spmv_x").unwrap();
    let y_expect = golden.f64_vec("spmv_y").unwrap();
    let mut y = vec![0.0; x.len()];
    b.mul_vec(&x, &mut y);
    let dev = hbmc::util::max_abs_diff(&y, &y_expect);
    assert!(dev < 1e-11, "rust SpMV deviates from python: {dev}");
}
