//! Property-based tests over randomized inputs (a self-contained harness —
//! no `proptest` in the offline crate set; `util::rng::Rng` provides the
//! deterministic case generator, failures print the seed).

use hbmc::config::{OrderingKind, SolverConfig, SpmvKind};
use hbmc::coordinator::pool::Pool;
use hbmc::error::HbmcError;
use hbmc::factor::ic0::{ic0, ic0_auto};
use hbmc::factor::split::{SellTriFactors, TriFactors};
use hbmc::ordering::bmc::{bmc_order, check_block_independence};
use hbmc::ordering::graph::{er_condition_holds, orderings_equivalent, Adjacency};
use hbmc::ordering::hbmc::{check_level2_diagonal, hbmc_order};
use hbmc::ordering::mc::mc_order;
use hbmc::ordering::perm::Perm;
use hbmc::solver::trisolve_hbmc::{self, HbmcMeta, KernelPath};
use hbmc::solver::trisolve_serial;
use hbmc::sparse::coo::Coo;
use hbmc::sparse::csr::Csr;
use hbmc::sparse::sell::Sell;
use hbmc::util::rng::Rng;

/// Random connected-ish SPD matrix with varying density.
fn random_spd(rng: &mut Rng) -> Csr {
    let n = 20 + rng.below(180);
    let extra = 1 + rng.below(4);
    let mut coo = Coo::new(n);
    let mut diag = vec![0.1f64; n];
    for i in 0..n {
        // chain edge keeps it connected
        if i + 1 < n {
            let v = rng.range_f64(0.2, 1.0);
            coo.push_sym(i, i + 1, -v);
            diag[i] += v;
            diag[i + 1] += v;
        }
        for _ in 0..extra {
            let j = rng.below(n);
            if j != i {
                let v = rng.range_f64(0.05, 0.6);
                coo.push_sym(i, j, -v);
                diag[i] += v;
                diag[j] += v;
            }
        }
    }
    for (i, d) in diag.iter().enumerate() {
        coo.push(i, i, d + 0.5);
    }
    coo.to_csr()
}

const CASES: u64 = 25;

#[test]
fn prop_hbmc_equivalent_and_structured() {
    for seed in 0..CASES {
        let mut rng = Rng::new(1000 + seed);
        let a = random_spd(&mut rng);
        let bs = [2usize, 4, 8, 16][rng.below(4)];
        let w = [2usize, 4, 8][rng.below(3)];
        let ord = hbmc_order(&a, bs, w);
        assert!(
            orderings_equivalent(&a, &ord.bmc.perm, &ord.perm),
            "seed={seed} bs={bs} w={w}"
        );
        let b = a.permute_sym(&ord.perm);
        assert_eq!(check_level2_diagonal(&b, &ord), None, "seed={seed}");
        assert!(er_condition_holds(&b, &Perm::identity(b.n())), "seed={seed}");
    }
}

#[test]
fn prop_bmc_blocks_independent() {
    for seed in 0..CASES {
        let mut rng = Rng::new(2000 + seed);
        let a = random_spd(&mut rng);
        let bs = [2usize, 8, 32][rng.below(3)];
        let ord = bmc_order(&a, bs);
        let b = a.permute_sym(&ord.perm);
        assert_eq!(check_block_independence(&b, &ord), None, "seed={seed} bs={bs}");
    }
}

#[test]
fn prop_mc_colors_are_independent_sets() {
    for seed in 0..CASES {
        let mut rng = Rng::new(3000 + seed);
        let a = random_spd(&mut rng);
        let mc = mc_order(&a);
        let b = a.permute_sym(&mc.perm);
        for c in 0..mc.num_colors {
            for i in mc.color_ptr[c]..mc.color_ptr[c + 1] {
                let (cols, _) = b.row(i);
                for &j in cols {
                    let j = j as usize;
                    assert!(
                        j == i || j < mc.color_ptr[c] || j >= mc.color_ptr[c + 1],
                        "seed={seed} intra-color edge"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_hbmc_trisolve_matches_serial_all_paths() {
    let have512 = trisolve_hbmc::select_path(8, true) == KernelPath::Avx512W8;
    let have2 = trisolve_hbmc::select_path(4, true) == KernelPath::Avx2W4;
    for seed in 0..CASES {
        let mut rng = Rng::new(4000 + seed);
        let a = random_spd(&mut rng);
        let bs = [2usize, 4, 8][rng.below(3)];
        let w = [4usize, 8][rng.below(2)];
        let ord = hbmc_order(&a, bs, w);
        let b = a.permute_sym(&ord.perm);
        let f = ic0(&b, 0.0).unwrap();
        let tri = TriFactors::from_ic(&f);
        let sell = SellTriFactors::from_tri(&tri, w);
        let meta = HbmcMeta::from_ordering(&ord);
        let n = b.n();
        let r: Vec<f64> = (0..n).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut y_ref = vec![0.0; n];
        trisolve_serial::forward(&tri, &r, &mut y_ref);
        let mut z_ref = vec![0.0; n];
        trisolve_serial::backward(&tri, &y_ref, &mut z_ref);

        let mut paths = vec![KernelPath::Scalar];
        if w == 8 && have512 {
            paths.push(KernelPath::Avx512W8);
        }
        if w == 4 && have2 {
            paths.push(KernelPath::Avx2W4);
        }
        for path in paths {
            let pool = Pool::new(1 + rng.below(3));
            let mut y = vec![0.0; n];
            trisolve_hbmc::forward(&meta, &sell, &r, &mut y, &pool, path);
            assert!(
                hbmc::util::max_abs_diff(&y, &y_ref) < 1e-11,
                "fwd seed={seed} path={}",
                path.name()
            );
            let mut z = vec![0.0; n];
            trisolve_hbmc::backward(&meta, &sell, &y, &mut z, &pool, path);
            assert!(
                hbmc::util::max_abs_diff(&z, &z_ref) < 1e-11,
                "bwd seed={seed} path={}",
                path.name()
            );
        }
    }
}

#[test]
fn prop_sell_spmv_equals_csr() {
    for seed in 0..CASES {
        let mut rng = Rng::new(5000 + seed);
        let a = random_spd(&mut rng);
        let c = [2usize, 4, 8][rng.below(3)];
        let sell = Sell::from_csr(&a, c);
        let x: Vec<f64> = (0..a.n()).map(|_| rng.range_f64(-1.0, 1.0)).collect();
        let mut y1 = vec![0.0; a.n()];
        let mut y2 = vec![0.0; a.n()];
        a.mul_vec(&x, &mut y1);
        sell.mul_vec(&x, &mut y2);
        assert!(hbmc::util::max_abs_diff(&y1, &y2) < 1e-12, "seed={seed} c={c}");
    }
}

#[test]
fn prop_ic0_preserves_pattern_and_positivity() {
    for seed in 0..CASES {
        let mut rng = Rng::new(6000 + seed);
        let a = random_spd(&mut rng);
        let f = ic0(&a, 0.0).unwrap();
        assert_eq!(f.lower.nnz(), a.lower_strict().nnz(), "seed={seed}");
        assert!(f.diag.iter().all(|&d| d > 0.0 && d.is_finite()), "seed={seed}");
    }
}

#[test]
fn prop_full_solve_reaches_tolerance() {
    for seed in 0..10 {
        let mut rng = Rng::new(7000 + seed);
        let a = random_spd(&mut rng);
        let mut b = vec![0.0; a.n()];
        a.mul_vec(&vec![1.0; a.n()], &mut b);
        let cfg = SolverConfig {
            ordering: [OrderingKind::Mc, OrderingKind::Bmc, OrderingKind::Hbmc][rng.below(3)],
            bs: [4usize, 8][rng.below(2)],
            w: 4,
            spmv: [SpmvKind::Crs, SpmvKind::Sell][rng.below(2)],
            threads: 1 + rng.below(2),
            rtol: 1e-8,
            ..Default::default()
        };
        let rep = hbmc::coordinator::driver::solve_opts(
            &a,
            &b,
            &cfg,
            &hbmc::coordinator::driver::SolveOptions::with_solution(),
        )
        .unwrap();
        assert!(rep.converged, "seed={seed} cfg={:?}", cfg.ordering);
        let sol = rep.solution.as_ref().unwrap();
        let err = sol.iter().map(|x| (x - 1.0).abs()).fold(0.0, f64::max);
        assert!(err < 1e-5, "seed={seed} err={err}");
    }
}

/// Kershaw's 4×4 matrix: symmetric positive definite (smallest eigenvalue
/// 3 − 2√2 ≈ 0.17) yet plain IC(0) breaks down on it — the mixed-sign
/// off-diagonals drive the last pivot negative. `scale` stretches the whole
/// block (pivots scale linearly, so the breakdown survives); `diag_delta`
/// shrinks the diagonal toward the indefinite edge (safe below ~0.17).
fn kershaw_block(coo: &mut Coo, base: usize, scale: f64, diag_delta: f64) {
    for &(i, j, v) in &[(0usize, 1usize, -2.0), (1, 2, -2.0), (2, 3, -2.0), (0, 3, 2.0)] {
        coo.push_sym(base + i, base + j, scale * v);
    }
    for i in 0..4 {
        coo.push(base + i, base + i, scale * (3.0 - diag_delta));
    }
}

/// Breakdown recovery end to end: matrices whose diagonals sit close enough
/// to the indefinite edge that plain IC(0) fails must (a) fail *typed*,
/// naming the pivot row, (b) be recovered by `ic0_auto`'s shift escalation,
/// and (c) still solve through the driver — whose plan build runs the same
/// escalation — in no more iterations (+10% headroom) than a config that
/// passes the recovered shift explicitly.
#[test]
fn prop_ic0_auto_recovers_near_indefinite_matrices() {
    let mut induced = 0usize;
    for seed in 0..10u64 {
        let mut rng = Rng::new(10_000 + seed);
        let blocks = 3 + rng.below(6);
        let n = 4 * blocks;
        let mut coo = Coo::new(n);
        for b in 0..blocks {
            kershaw_block(&mut coo, 4 * b, rng.range_f64(0.5, 2.0), rng.range_f64(0.0, 0.1));
        }
        let a = coo.to_csr();

        match ic0(&a, 0.0) {
            Err(HbmcError::BreakdownInFactorization { row: Some(r), shift, .. }) => {
                assert!(r < n, "seed={seed} row {r} out of range");
                assert_eq!(shift, 0.0, "seed={seed}");
                induced += 1;
            }
            Err(other) => panic!("seed={seed}: expected a rowful breakdown, got {other:?}"),
            Ok(_) => panic!("seed={seed}: generator failed to induce an IC(0) breakdown"),
        }

        let f = ic0_auto(&a, 0.0).unwrap();
        assert!(f.shift > 0.0, "seed={seed}: recovery must have escalated the shift");
        assert!(f.diag.iter().all(|&d| d > 0.0 && d.is_finite()), "seed={seed}");

        let mut b = vec![0.0; n];
        a.mul_vec(&vec![1.0; n], &mut b);
        let cfg = |shift: f64| SolverConfig {
            ordering: OrderingKind::Natural,
            bs: 4,
            w: 2,
            rtol: 1e-8,
            shift,
            ..Default::default()
        };
        let opts = hbmc::coordinator::driver::SolveOptions::with_solution;
        let recovered =
            hbmc::coordinator::driver::solve_opts(&a, &b, &cfg(0.0), &opts()).unwrap();
        let informed =
            hbmc::coordinator::driver::solve_opts(&a, &b, &cfg(f.shift), &opts()).unwrap();
        assert!(recovered.converged, "seed={seed}: recovered factor must still drive CG home");
        assert!(informed.converged, "seed={seed}");
        assert!(
            recovered.iterations <= informed.iterations + informed.iterations / 10,
            "seed={seed}: auto-recovery may not cost extra iterations ({} vs {})",
            recovered.iterations,
            informed.iterations
        );
        let sol = recovered.solution.as_ref().unwrap();
        let err = sol.iter().map(|x| (x - 1.0).abs()).fold(0.0, f64::max);
        assert!(err < 1e-5, "seed={seed} err={err}");
    }
    assert!(induced >= 1, "at least one case must exercise the breakdown path");
}

#[test]
fn prop_permutation_roundtrip() {
    for seed in 0..CASES {
        let mut rng = Rng::new(8000 + seed);
        let n = 5 + rng.below(200);
        let n_new = n + rng.below(50);
        // random injective map
        let mut slots: Vec<u32> = (0..n_new as u32).collect();
        rng.shuffle(&mut slots);
        let p = Perm::padded(slots[..n].to_vec(), n_new).unwrap();
        let x: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let y = p.apply_vec(&x, -7.0);
        assert_eq!(p.unapply_vec(&y), x, "seed={seed}");
    }
}

#[test]
fn prop_coloring_proper_on_adjacency() {
    for seed in 0..CASES {
        let mut rng = Rng::new(9000 + seed);
        let a = random_spd(&mut rng);
        let adj = Adjacency::from_csr(&a);
        let col = hbmc::ordering::coloring::greedy_color(adj.n(), |v| adj.neighbors(v).to_vec());
        assert!(col.is_proper(|v| adj.neighbors(v).to_vec()), "seed={seed}");
        assert!(col.num_colors <= adj.max_degree() + 1, "seed={seed}");
    }
}
