//! Contract of admission control + observability (the obs/ subsystem):
//!
//! * **bounded queue** — with `max_queue_depth` set, submits beyond the
//!   bound fail *fast* and *typed* (`HbmcError::Overloaded`), never block,
//!   and never silently drop a job; the bound counts jobs staged into an
//!   open batch window, so it cannot be dodged by racing the dispatcher;
//! * **per-handle quota** — `max_inflight_per_handle` caps one matrix's
//!   in-flight jobs without coupling handles to each other, and slots are
//!   returned at every terminal transition;
//! * **shedding** — a job whose deadline expires while queued is shed at
//!   dispatch (typed failure, counted, visible in /metrics), and a zero
//!   budget is rejected synchronously at submit;
//! * **passivity** — observability on (tracing every job, bounds set)
//!   changes no numerics: results stay bitwise-identical to the
//!   un-instrumented one-shot path.

use std::sync::{Arc, Barrier};
use std::thread;
use std::time::{Duration, Instant};

use hbmc::api::{HbmcError, MatrixHandle, SolveRequest, SolverService};
use hbmc::config::{OrderingKind, Scale, SolverConfig};
use hbmc::coordinator::driver::{solve_opts, SolveOptions};
use hbmc::gen::suite;

fn tiny_cfg(ordering: OrderingKind) -> SolverConfig {
    SolverConfig { ordering, bs: 8, w: 4, threads: 1, rtol: 1e-7, ..Default::default() }
}

/// Warm one (handle, default-config) plan without waiting out a long batch
/// window: deadline-carrying jobs flush the window immediately, and a 300s
/// budget can never be shed.
fn warm(service: &SolverService, handle: MatrixHandle, b: &[f64]) {
    let req = SolveRequest::new().deadline(Duration::from_secs(300));
    assert!(service.submit(handle, b, &req).unwrap().wait().unwrap().report.converged);
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The depth bound is exact and includes batch-window staging: while one
/// job is held staged in an open window, `limit - 1` more jobs fit and the
/// next is rejected with the documented payload — synchronously.
#[test]
fn depth_bound_is_exact_and_counts_staged_jobs() {
    let d1 = suite::dataset("g3_circuit", Scale::Tiny);
    let d2 = suite::dataset("thermal2", Scale::Tiny);
    let mut cfg = tiny_cfg(OrderingKind::Hbmc);
    cfg.queue.max_queue_depth = Some(4);
    cfg.queue.max_batch = 16;
    // Long flush window: the blocker below holds the dispatcher (and one
    // staged depth slot) while the assertions run.
    cfg.queue.max_wait = Duration::from_millis(900);
    let service = SolverService::with_config(cfg).unwrap();
    let h1 = service.register_matrix(d1.matrix.clone());
    let h2 = service.register_matrix(d2.matrix.clone());
    // Warm both plans so nothing below waits on a build.
    warm(&service, h1, &d1.b);
    warm(&service, h2, &d2.b);

    // Blocker: opens a batch window for h1's key. Whether it is still
    // queued or already staged, it occupies exactly one depth slot — the
    // satellite fix this test pins down (staged jobs used to vanish from
    // the depth, letting submitters overshoot the bound).
    let blocker = service.submit(h1, &d1.b, &SolveRequest::new()).unwrap();
    assert_eq!(service.stats().queue_depth, 1, "blocker must stay visible in the gauge");

    // limit - 1 more jobs fit (different key: they queue behind the window
    // instead of being absorbed into it)...
    let fillers: Vec<_> =
        (0..3).map(|_| service.submit(h2, &d2.b, &SolveRequest::new()).unwrap()).collect();
    assert_eq!(service.stats().queue_depth, 4);

    // ...and the next submit is rejected, typed, with the exact payload.
    let t0 = Instant::now();
    let err = service.submit(h2, &d2.b, &SolveRequest::new()).unwrap_err();
    let elapsed = t0.elapsed();
    match err {
        HbmcError::Overloaded { depth, limit } => {
            assert_eq!(limit, 4);
            assert_eq!(depth, 4);
        }
        other => panic!("expected Overloaded, got {other:?}"),
    }
    assert!(elapsed < Duration::from_millis(400), "rejection must not block: {elapsed:?}");

    // Everything admitted still completes, and the books balance.
    assert!(blocker.wait().unwrap().report.converged);
    for f in fillers {
        assert!(f.wait().unwrap().report.converged);
    }
    let st = service.stats();
    assert_eq!(st.queue_depth, 0, "queue must drain back to zero");
    assert_eq!(st.overloaded, 1);
    assert_eq!(st.solves, 2 + 4, "rejected submits must never reach the solver");
}

/// Flooding a bounded queue from many threads yields fast typed
/// rejections: every submit either enters the queue or returns
/// `Overloaded` within a bound far below the batch window, and the
/// accept/reject split is conserved and mirrored in the stats.
#[test]
fn flood_fails_fast_and_conserves_jobs() {
    let d1 = suite::dataset("g3_circuit", Scale::Tiny);
    let d2 = suite::dataset("thermal2", Scale::Tiny);
    let mut cfg = tiny_cfg(OrderingKind::Hbmc);
    cfg.queue.max_queue_depth = Some(4);
    cfg.queue.max_batch = 16;
    cfg.queue.max_wait = Duration::from_millis(900);
    let service = Arc::new(SolverService::with_config(cfg).unwrap());
    let h1 = service.register_matrix(d1.matrix.clone());
    let h2 = service.register_matrix(d2.matrix.clone());
    warm(&service, h1, &d1.b);
    warm(&service, h2, &d2.b);

    // Hold the dispatcher in h1's batch window so the flood races a queue
    // of effective capacity 3 (the blocker keeps one staged slot).
    let blocker = service.submit(h1, &d1.b, &SolveRequest::new()).unwrap();

    const THREADS: usize = 4;
    const PER_THREAD: usize = 25;
    let barrier = Arc::new(Barrier::new(THREADS));
    let workers: Vec<_> = (0..THREADS)
        .map(|_| {
            let service = Arc::clone(&service);
            let barrier = Arc::clone(&barrier);
            let b = d2.b.clone();
            thread::spawn(move || {
                let mut accepted = Vec::new();
                let (mut rejected, mut max_submit) = (0usize, Duration::ZERO);
                barrier.wait();
                for _ in 0..PER_THREAD {
                    let t0 = Instant::now();
                    let outcome = service.submit(h2, &b, &SolveRequest::new());
                    max_submit = max_submit.max(t0.elapsed());
                    match outcome {
                        Ok(job) => accepted.push(job),
                        Err(HbmcError::Overloaded { limit, .. }) => {
                            assert_eq!(limit, 4);
                            rejected += 1;
                        }
                        Err(e) => panic!("flood must only fail Overloaded, got {e:?}"),
                    }
                }
                (accepted, rejected, max_submit)
            })
        })
        .collect();
    let (mut accepted, mut rejected, mut max_submit) = (Vec::new(), 0usize, Duration::ZERO);
    for t in workers {
        let (a, r, m) = t.join().expect("flood thread panicked");
        accepted.extend(a);
        rejected += r;
        max_submit = max_submit.max(m);
    }

    let total = THREADS * PER_THREAD;
    assert_eq!(accepted.len() + rejected, total, "no submit may be lost or double-counted");
    // The flood outpaces a depth-4 queue behind a 900ms window by orders
    // of magnitude; the loose floor only guards against a pathological CI
    // stall making every submit land after the window.
    assert!(rejected >= total - 10, "expected a flooded queue, got {rejected} rejections");
    // Fail-fast: far under the 900ms the queue would make a *blocking*
    // submitter wait.
    assert!(max_submit < Duration::from_millis(400), "submit blocked: {max_submit:?}");
    assert!(blocker.wait().unwrap().report.converged);
    for job in accepted {
        assert!(job.wait().unwrap().report.converged);
    }
    let st = service.stats();
    assert_eq!(st.overloaded, rejected as u64);
    assert_eq!(st.queue_depth, 0);
    let text = service.metrics_text();
    assert!(text.contains(&format!("hbmc_overloaded_total{{reason=\"queue_depth\"}} {rejected}")));
    assert!(text.contains("hbmc_overloaded_total{reason=\"inflight\"} 0"));
}

/// `max_inflight_per_handle` caps one handle without touching another, and
/// slots come back once jobs reach a terminal state.
#[test]
fn inflight_quota_is_per_handle_and_released() {
    let d1 = suite::dataset("g3_circuit", Scale::Tiny);
    let d2 = suite::dataset("thermal2", Scale::Tiny);
    let mut cfg = tiny_cfg(OrderingKind::Hbmc);
    cfg.queue.max_inflight_per_handle = Some(2);
    cfg.queue.max_batch = 16;
    // The two h1 jobs are absorbed into one batch window and cannot reach
    // a terminal state before the window flushes — their quota slots stay
    // held for the whole window.
    cfg.queue.max_wait = Duration::from_millis(900);
    let service = SolverService::with_config(cfg).unwrap();
    let h1 = service.register_matrix(d1.matrix.clone());
    let h2 = service.register_matrix(d2.matrix.clone());
    warm(&service, h1, &d1.b);
    warm(&service, h2, &d2.b);

    let a = service.submit(h1, &d1.b, &SolveRequest::new()).unwrap();
    let b = service.submit(h1, &d1.b, &SolveRequest::new()).unwrap();
    let err = service.submit(h1, &d1.b, &SolveRequest::new()).unwrap_err();
    match err {
        HbmcError::Overloaded { depth, limit } => {
            assert_eq!(limit, 2);
            assert_eq!(depth, 2, "both slots were held");
        }
        other => panic!("expected Overloaded, got {other:?}"),
    }
    // A different handle has its own quota: this submit must be admitted
    // while h1 is saturated.
    let c = service.submit(h2, &d2.b, &SolveRequest::new()).unwrap();

    assert!(a.wait().unwrap().report.converged);
    assert!(b.wait().unwrap().report.converged);
    assert!(c.wait().unwrap().report.converged);
    // Terminal jobs returned their slots: h1 accepts again.
    let again = service.submit(h1, &d1.b, &SolveRequest::new()).unwrap();
    assert!(again.wait().unwrap().report.converged);
    let st = service.stats();
    assert_eq!(st.overloaded, 1);
    assert!(service
        .metrics_text()
        .contains("hbmc_overloaded_total{reason=\"inflight\"} 1"));
}

/// Satellite regression: a submit whose deadline budget is already zero is
/// rejected synchronously — no handle, no queue traffic, no dispatcher
/// involvement.
#[test]
fn zero_deadline_rejected_at_submit() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let service = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
    let handle = service.register_matrix(d.matrix.clone());
    let err = service
        .submit(handle, &d.b, &SolveRequest::new().deadline(Duration::ZERO))
        .unwrap_err();
    assert!(matches!(err, HbmcError::DeadlineExceeded { .. }), "{err:?}");
    let st = service.stats();
    assert_eq!(st.solves, 0);
    assert_eq!(st.queue_depth, 0);
    assert_eq!(st.shed, 0, "a synchronous rejection is not a shed");
}

/// An expired-at-dispatch job is shed: typed failure for the caller, a
/// `shed` tick in the stats, and a visible `hbmc_shed_total` sample in the
/// Prometheus text.
#[test]
fn expired_jobs_are_shed_and_counted() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let service = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
    let handle = service.register_matrix(d.matrix.clone());
    service.solve(handle, &d.b).unwrap();
    // Give the dispatcher a backlog so the doomed job demonstrably sits
    // queued behind real work (it would be shed even on an idle service —
    // 1ns is always spent by claim time).
    let blockers: Vec<_> =
        (0..6).map(|_| service.submit(handle, &d.b, &SolveRequest::new()).unwrap()).collect();
    let doomed = service
        .submit(handle, &d.b, &SolveRequest::new().deadline(Duration::from_nanos(1)))
        .unwrap();
    let err = doomed.wait().unwrap_err();
    assert!(matches!(err, HbmcError::DeadlineExceeded { .. }), "{err:?}");
    for job in blockers {
        assert!(job.wait().unwrap().report.converged);
    }
    let st = service.stats();
    assert_eq!(st.shed, 1);
    assert_eq!(st.solves, 7, "the shed job must never run");
    let text = service.metrics_text();
    assert!(text.contains("# TYPE hbmc_shed_total counter"));
    assert!(text.contains("hbmc_shed_total 1"));
}

/// Observability is passive: with per-job tracing, admission bounds and
/// the full metrics pipeline enabled, solver outputs are bitwise-identical
/// to the un-instrumented one-shot path.
#[test]
fn results_identical_with_observability_on() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let mut cfg = tiny_cfg(OrderingKind::Hbmc);
    cfg.queue.trace_sample = 1; // trace every job
    cfg.queue.max_queue_depth = Some(64);
    cfg.queue.max_inflight_per_handle = Some(8);
    let rhss: Vec<Vec<f64>> =
        (0..4).map(|k| d.b.iter().map(|v| v * (1.0 + k as f64)).collect()).collect();

    // Un-instrumented reference: the one-shot driver path, no service, no
    // queue, no observability.
    let mut ref_bits = Vec::new();
    for rhs in &rhss {
        let rep = solve_opts(&d.matrix, rhs, &cfg, &SolveOptions::with_solution()).unwrap();
        ref_bits.push(bits(rep.solution.as_ref().unwrap()));
    }

    let service = SolverService::with_config(cfg).unwrap();
    let handle = service.register_matrix(d.matrix.clone());
    let outs = service.solve_many(handle, &rhss).unwrap();
    for (k, out) in outs.iter().enumerate() {
        assert_eq!(
            bits(&out.x),
            ref_bits[k],
            "rhs {k}: instrumentation must not perturb the solve"
        );
    }
    // The pipeline actually observed the work it claims not to perturb.
    let trace = service.trace_json();
    for stage in ["\"submitted\"", "\"enqueued\"", "\"dispatched\"", "\"completed\""] {
        assert!(trace.contains(stage), "trace missing {stage}: {trace}");
    }
    let snap = service.metrics_snapshot();
    assert_eq!(snap.histogram("hbmc_solve_microseconds").unwrap().count, 4);
    assert_eq!(snap.histogram("hbmc_queue_wait_microseconds").unwrap().count, 4);
}

/// The rendered exposition is structurally valid Prometheus text: every
/// line is a comment or a `name[{labels}] value` sample, and histogram
/// `+Inf` buckets agree with their `_count` series.
#[test]
fn metrics_text_is_structurally_valid() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let service = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
    let handle = service.register_matrix(d.matrix.clone());
    service.solve(handle, &d.b).unwrap();
    let text = service.metrics_text();
    let mut inf_buckets = 0;
    for line in text.lines() {
        if line.starts_with("# HELP ") || line.starts_with("# TYPE ") {
            continue;
        }
        let (name, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line {line:?}"));
        assert!(!name.is_empty() && !name.starts_with('#'), "bad sample name in {line:?}");
        value.parse::<f64>().unwrap_or_else(|_| panic!("non-numeric value in {line:?}"));
        if let Some(prefix) = name.strip_suffix("_bucket{le=\"+Inf\"}") {
            inf_buckets += 1;
            let count_line = format!("{prefix}_count 1");
            assert!(
                text.contains(&count_line),
                "{prefix}: +Inf bucket must equal _count after one solve"
            );
        }
    }
    assert_eq!(inf_buckets, 5, "one +Inf bucket per histogram family");
}
