//! Chaos contract of the resil/ subsystem: deterministic fault injection
//! against the full service stack, and the dispatcher's recovery ladder
//! absorbing what it can.
//!
//! * **termination** — under injected worker panics and forced pivot
//!   breakdowns, across all five orderings, every submitted job reaches a
//!   terminal state: a successful (possibly retried) solve or a typed
//!   `HbmcError`; the accept/finish books balance exactly as in the
//!   overload tests;
//! * **containment** — a pool poisoned by a lockstep worker panic is
//!   *drained* (bounded join) and rebuilt, never leaked: the process-wide
//!   leaked-worker counter stays flat across a recovery, and healthy jobs
//!   co-queued on other handles return bitwise-identical results to a
//!   fault-free run;
//! * **accounting** — every rung of the ladder stamps the report
//!   (`retries`/`attempts`), ticks `hbmc_retries_total{cause=…}` /
//!   `hbmc_pool_rebuilds_total`, and leaves a `retried` trace event;
//! * **passivity** — with injection disabled, the armed resilience layer
//!   (retry budget + breaker threshold) changes neither the bitwise
//!   outputs nor the dispatch counts of the fused path.

use std::time::{Duration, Instant};

use hbmc::api::{HbmcError, SolveRequest, SolverService};
use hbmc::config::{OrderingKind, Scale, SolverConfig};
use hbmc::coordinator::driver::{solve_opts, SolveOptions};
use hbmc::coordinator::pool::leaked_workers;
use hbmc::gen::suite;
use hbmc::resil::{FaultPhase, FaultSpec, RetryPolicy};

fn tiny_cfg(ordering: OrderingKind) -> SolverConfig {
    SolverConfig { ordering, bs: 8, w: 4, threads: 1, rtol: 1e-7, ..Default::default() }
}

fn chaos_cfg(ordering: OrderingKind, fault: FaultSpec, retries: u32) -> SolverConfig {
    SolverConfig {
        fault: Some(fault),
        retry: RetryPolicy::retries(retries),
        ..tiny_cfg(ordering)
    }
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Every pool thread panics in lockstep at the first in-solve barrier.
const PANIC_AT_0: FaultSpec = FaultSpec::WorkerPanic { phase: FaultPhase::Fwd, barrier: 0 };

/// A lockstep worker panic is absorbed by the panic rung: the poisoned
/// pool is drained (zero leaks — lockstep keeps the barrier generations
/// synchronized), the plan evicted, the job retried once on a fresh
/// session, and the retried result is bitwise-identical to a fault-free
/// run. The retry is visible in the report, the metrics, and the trace.
#[test]
fn worker_panic_recovers_on_a_rebuilt_pool_without_leaks() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let mut cfg = chaos_cfg(OrderingKind::Hbmc, PANIC_AT_0, 1);
    cfg.threads = 4;
    cfg.queue.trace_sample = 1;
    let leaked_before = leaked_workers();
    let service = SolverService::with_config(cfg.clone()).unwrap();
    let h = service.register_matrix(d.matrix.clone());
    let out = service.submit(h, &d.b, &SolveRequest::new()).unwrap().wait().unwrap();
    assert!(out.report.converged);
    assert_eq!(out.report.retries, 1);
    assert_eq!(out.report.attempts.len(), 1);
    assert_eq!(out.report.attempts[0].cause, "panic");
    assert!(
        out.report.attempts[0].action.contains("pool rebuilt"),
        "{}",
        out.report.attempts[0].action
    );
    assert_eq!(
        leaked_workers(),
        leaked_before,
        "a lockstep panic must drain clean: no detached workers"
    );

    // The recovered solve ran on a rebuilt plan + pool of the same config:
    // its output must be bitwise-identical to a never-faulted run.
    let mut clean = cfg.clone();
    clean.fault = None;
    let rep = solve_opts(&d.matrix, &d.b, &clean, &SolveOptions::with_solution()).unwrap();
    assert_eq!(bits(&out.x), bits(rep.solution.as_ref().unwrap()));

    let text = service.metrics_text();
    assert!(text.contains("hbmc_retries_total{cause=\"panic\"} 1"), "{text}");
    assert!(text.contains("hbmc_pool_rebuilds_total 1"), "{text}");
    let trace = service.trace_json();
    assert!(trace.contains("\"retried\""), "trace missing the retry event: {trace}");
}

/// Job-count conservation under chaos, across every ordering: with a
/// worker panic or a forced pivot breakdown injected, each submitted job
/// terminates — and with one retry of budget available for the single
/// injected fault, terminates *successfully*. The queue drains to zero
/// and no recovery leaks a worker thread.
#[test]
fn faults_across_all_orderings_terminate_every_job() {
    let d = suite::dataset("thermal2", Scale::Tiny);
    for ordering in [
        OrderingKind::Natural,
        OrderingKind::Mc,
        OrderingKind::Bmc,
        OrderingKind::Hbmc,
        OrderingKind::Level,
    ] {
        for fault in [PANIC_AT_0, FaultSpec::PivotBreakdown { row: 0 }] {
            let mut cfg = chaos_cfg(ordering, fault, 2);
            cfg.threads = 2;
            let leaked_before = leaked_workers();
            let service = SolverService::with_config(cfg).unwrap();
            let h = service.register_matrix(d.matrix.clone());
            const JOBS: usize = 3;
            let submitted: Vec<_> = (0..JOBS)
                .map(|k| {
                    let rhs: Vec<f64> = d.b.iter().map(|v| v * (1.0 + k as f64)).collect();
                    service.submit(h, &rhs, &SolveRequest::new()).unwrap()
                })
                .collect();
            let (mut ok, mut failed) = (0usize, 0usize);
            for job in submitted {
                match job.wait() {
                    Ok(out) => {
                        assert!(out.report.converged, "{ordering:?} under {fault}");
                        ok += 1;
                    }
                    Err(e) => {
                        // Typed and printable — never a propagated panic.
                        let _ = e.to_string();
                        failed += 1;
                    }
                }
            }
            assert_eq!(ok + failed, JOBS, "{ordering:?} under {fault}: job lost");
            assert_eq!(
                ok, JOBS,
                "{ordering:?} under {fault}: one fault within a 2-retry budget must be absorbed"
            );
            assert_eq!(service.stats().queue_depth, 0, "{ordering:?} under {fault}");
            assert_eq!(leaked_workers(), leaked_before, "{ordering:?} under {fault}: leak");
        }
    }
}

/// Fault isolation across handles: a panic injected into one matrix's
/// batch must not perturb healthy jobs co-queued for another matrix —
/// their results stay bitwise-identical to a fault-free run, with zero
/// retries on their reports.
#[test]
fn healthy_jobs_coqueued_with_a_faulty_one_are_unperturbed() {
    let d1 = suite::dataset("g3_circuit", Scale::Tiny); // fault lands here
    let d2 = suite::dataset("thermal2", Scale::Tiny); // healthy bystander
    let mut cfg = chaos_cfg(OrderingKind::Hbmc, PANIC_AT_0, 1);
    cfg.threads = 2;
    let mut clean = cfg.clone();
    clean.fault = None;
    let rhss: Vec<Vec<f64>> =
        (0..3).map(|k| d2.b.iter().map(|v| v * (1.0 + k as f64)).collect()).collect();
    let ref_bits: Vec<Vec<u64>> = rhss
        .iter()
        .map(|rhs| {
            let rep = solve_opts(&d2.matrix, rhs, &clean, &SolveOptions::with_solution()).unwrap();
            bits(rep.solution.as_ref().unwrap())
        })
        .collect();

    let service = SolverService::with_config(cfg).unwrap();
    let h1 = service.register_matrix(d1.matrix.clone());
    let h2 = service.register_matrix(d2.matrix.clone());
    // FIFO dispatch: the faulty job is submitted first, so its batch opens
    // first and the one-shot panic is consumed inside it.
    let faulty = service.submit(h1, &d1.b, &SolveRequest::new()).unwrap();
    let healthy: Vec<_> =
        rhss.iter().map(|rhs| service.submit(h2, rhs, &SolveRequest::new()).unwrap()).collect();
    let out = faulty.wait().unwrap();
    assert_eq!(out.report.retries, 1, "the fault must land on the faulty handle");
    for (k, job) in healthy.into_iter().enumerate() {
        let out = job.wait().unwrap();
        assert_eq!(out.report.retries, 0, "rhs {k}: bystander must not be retried");
        assert_eq!(bits(&out.x), ref_bits[k], "rhs {k}: bystander result perturbed");
    }
}

/// Passivity: the resilience layer armed but idle (retry budget, breaker
/// threshold, no fault) changes neither the bitwise output nor the fused
/// path's dispatch count.
#[test]
fn disabled_injection_is_passive() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let mut plain = tiny_cfg(OrderingKind::Hbmc);
    plain.threads = 2;
    let mut armed = plain.clone();
    armed.retry = RetryPolicy::retries(3);
    armed.queue.breaker_threshold = Some(4);

    let run = |cfg: &SolverConfig| {
        let service = SolverService::with_config(cfg.clone()).unwrap();
        let h = service.register_matrix(d.matrix.clone());
        let out = service.submit(h, &d.b, &SolveRequest::new()).unwrap().wait().unwrap();
        (bits(&out.x), out.report.iterations, out.report.dispatches, out.report.retries)
    };
    let (bits_plain, iters_plain, disp_plain, retries_plain) = run(&plain);
    let (bits_armed, iters_armed, disp_armed, retries_armed) = run(&armed);
    assert_eq!(bits_plain, bits_armed, "armed-but-idle resilience perturbed the solve");
    assert_eq!(iters_plain, iters_armed);
    assert_eq!(disp_plain, disp_armed, "dispatch count must not change");
    assert_eq!((retries_plain, retries_armed), (0, 0));
}

/// A forced pivot breakdown at batch open walks the shift-escalation
/// rung: the re-plan uses the first rung of the doubling schedule above
/// the configured shift (0.0 → 0.02) and the job succeeds with the
/// escalation on its report.
#[test]
fn forced_pivot_breakdown_escalates_the_shift() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let cfg = chaos_cfg(OrderingKind::Hbmc, FaultSpec::PivotBreakdown { row: 0 }, 1);
    let service = SolverService::with_config(cfg).unwrap();
    let h = service.register_matrix(d.matrix.clone());
    let out = service.submit(h, &d.b, &SolveRequest::new()).unwrap().wait().unwrap();
    assert!(out.report.converged);
    assert_eq!(out.report.retries, 1);
    assert_eq!(out.report.attempts[0].cause, "breakdown_factorization");
    assert!(
        out.report.attempts[0].action.contains("escalated shift 0.02"),
        "{}",
        out.report.attempts[0].action
    );
    assert!(service
        .metrics_text()
        .contains("hbmc_retries_total{cause=\"breakdown_factorization\"} 1"));
}

/// An injected NaN in the dispatched right-hand side *copy* is caught by
/// the fused loop's breakdown detection (typed, no new syncs), and the
/// retry runs on the clean queued rhs: the job still converges.
#[test]
fn nan_rhs_fault_is_detected_and_retried_on_the_clean_rhs() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let cfg = chaos_cfg(OrderingKind::Hbmc, FaultSpec::NanRhs { index: 3 }, 1);
    let service = SolverService::with_config(cfg).unwrap();
    let h = service.register_matrix(d.matrix.clone());
    let out = service.submit(h, &d.b, &SolveRequest::new()).unwrap().wait().unwrap();
    assert!(out.report.converged);
    assert!(out.x.iter().all(|v| v.is_finite()));
    assert_eq!(out.report.retries, 1);
    assert_eq!(out.report.attempts[0].cause, "breakdown_iteration");
    assert!(
        out.report.attempts[0].action.contains("non-finite"),
        "{}",
        out.report.attempts[0].action
    );
    assert!(service
        .metrics_text()
        .contains("hbmc_retries_total{cause=\"breakdown_iteration\"} 1"));
}

/// A NaN-poisoned factor diagonal surfaces as `BreakdownInIteration`; the
/// rung evicts the poisoned plan (so the rebuild re-factorizes instead of
/// re-checking the bad Arc out of the cache) and the retry converges.
#[test]
fn nan_factor_fault_evicts_the_poisoned_plan_and_retries() {
    let d = suite::dataset("thermal2", Scale::Tiny);
    let cfg = chaos_cfg(OrderingKind::Bmc, FaultSpec::NanFactor { index: 0 }, 1);
    let service = SolverService::with_config(cfg).unwrap();
    let h = service.register_matrix(d.matrix.clone());
    let out = service.submit(h, &d.b, &SolveRequest::new()).unwrap().wait().unwrap();
    assert!(out.report.converged);
    assert_eq!(out.report.retries, 1);
    assert_eq!(out.report.attempts[0].cause, "breakdown_iteration");
}

/// Without retry budget, an injected breakdown is a *typed* terminal
/// failure — the ladder never silently swallows a fault it cannot retry.
#[test]
fn exhausted_budget_fails_typed() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let cfg = chaos_cfg(OrderingKind::Hbmc, FaultSpec::PivotBreakdown { row: 0 }, 0);
    let service = SolverService::with_config(cfg).unwrap();
    let h = service.register_matrix(d.matrix.clone());
    let err = service.submit(h, &d.b, &SolveRequest::new()).unwrap().wait().unwrap_err();
    assert!(matches!(err, HbmcError::BreakdownInFactorization { .. }), "{err:?}");
    assert_eq!(service.stats().solves, 0, "a failed build must never count a solve");
}

/// Injected dispatcher latency is consumed before exactly one batch: the
/// solve still succeeds, is not counted as a retry, and the extra latency
/// is observable on the job's wall clock.
#[test]
fn dispatch_delay_fault_stalls_one_batch_without_failing_it() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let cfg = chaos_cfg(OrderingKind::Hbmc, FaultSpec::DispatchDelay { micros: 120_000 }, 0);
    let service = SolverService::with_config(cfg).unwrap();
    let h = service.register_matrix(d.matrix.clone());
    let t0 = Instant::now();
    // A generous deadline flushes the batch window immediately (the warm()
    // idiom from the overload tests) without ever shedding the job.
    let req = SolveRequest::new().deadline(Duration::from_secs(300));
    let out = service.submit(h, &d.b, &req).unwrap().wait().unwrap();
    assert!(out.report.converged);
    assert_eq!(out.report.retries, 0, "latency is not a failure");
    assert!(
        t0.elapsed() >= Duration::from_millis(120),
        "the injected delay must precede the batch: {:?}",
        t0.elapsed()
    );
}

/// The not-converged rung: a colored ordering stalling against a hard
/// iteration cap falls back once to the level-scheduled plan, which keeps
/// natural-ordering convergence (§5.2's trade-off, inverted for rescue).
#[test]
fn stalled_colored_solve_falls_back_to_level_ordering() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let iters_hbmc = solve_opts(&d.matrix, &d.b, &tiny_cfg(OrderingKind::Hbmc), &SolveOptions::default())
        .unwrap()
        .iterations;
    let iters_level =
        solve_opts(&d.matrix, &d.b, &tiny_cfg(OrderingKind::Level), &SolveOptions::default())
            .unwrap()
            .iterations;

    let mut cfg = tiny_cfg(OrderingKind::Hbmc);
    cfg.retry = RetryPolicy::retries(1);
    let service = SolverService::with_config(cfg).unwrap();
    let h = service.register_matrix(d.matrix.clone());
    if iters_level < iters_hbmc {
        // Cap at exactly the level-ordering count: the colored first
        // attempt stalls, the level fallback fits under the same cap.
        let req = SolveRequest::new().max_iters(iters_level).require_convergence();
        let out = service.submit(h, &d.b, &req).unwrap().wait().unwrap();
        assert!(out.report.converged);
        assert!(out.report.iterations <= iters_level);
        assert_eq!(out.report.retries, 1);
        assert_eq!(out.report.attempts[0].cause, "not_converged");
        assert!(
            out.report.attempts[0].action.contains("level"),
            "{}",
            out.report.attempts[0].action
        );
    } else {
        // Degenerate dataset (no convergence gap to exploit): the rung
        // still fires, and the fallback's own stall is the final typed
        // error rather than a silent success.
        let req = SolveRequest::new().max_iters(1).require_convergence();
        let err = service.submit(h, &d.b, &req).unwrap().wait().unwrap_err();
        assert!(matches!(err, HbmcError::NotConverged { .. }), "{err:?}");
    }
    assert!(service
        .metrics_text()
        .contains("hbmc_retries_total{cause=\"not_converged\"} 1"));
}
