//! Acceptance suite for the in-region flight recorder (ISSUE 10).
//!
//! Contract under test:
//!
//! * arming [`ExecOptions::profile`] is **numerically inert**: profiled
//!   solves are bitwise identical to unprofiled ones — same residual
//!   history bits, same solution bits — across all five orderings ×
//!   threads ∈ {1, 4} × SpMV ∈ {CRS, SELL};
//! * profiling adds **zero pool barriers** and keeps the fused solve at
//!   exactly one dispatch (the recorder stamps existing phase boundaries);
//! * the drained [`PhaseProfile`] is sane: non-empty phase totals, shares
//!   that sum to one, substantial coverage of thread-time, a complete
//!   (undropped) timeline at default capacity;
//! * the chrome-trace export of a *real* solve is structurally valid:
//!   parseable JSON whose events carry canonical phase names and form a
//!   monotone, non-overlapping timeline per thread;
//! * the profile rides the whole API stack (`SolveOptions::profiled()` →
//!   `SolveReport::profile`), and the service's lifecycle `trace_json()`
//!   is well-formed JSON, not just greppable text.

use hbmc::api::SolverService;
use hbmc::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::driver::SolveOptions;
use hbmc::coordinator::pool::Pool;
use hbmc::gen::suite;
use hbmc::obs::{chrome_trace_json, PhaseProfile, PHASE_NAMES};
use hbmc::solver::plan::{ExecOptions, SolveOutcome, SolverPlan};
use hbmc::util::json::Json;

const ORDERINGS: [OrderingKind; 5] = [
    OrderingKind::Natural,
    OrderingKind::Mc,
    OrderingKind::Bmc,
    OrderingKind::Hbmc,
    OrderingKind::Level,
];

fn cfg_for(ordering: OrderingKind, spmv: SpmvKind, shift: f64) -> SolverConfig {
    SolverConfig {
        ordering,
        bs: 8,
        w: 4,
        spmv,
        shift,
        rtol: 1e-6,
        threads: 1,
        ..Default::default()
    }
}

fn run(plan: &SolverPlan, b: &[f64], nt: usize, profile: bool) -> SolveOutcome {
    let pool = Pool::new(nt);
    plan.execute(&pool, b, &ExecOptions { record_history: true, profile, ..Default::default() })
        .expect("solve")
}

fn assert_bitwise_equal(a: &SolveOutcome, b: &SolveOutcome, what: &str) {
    assert_eq!(a.cg.iterations, b.cg.iterations, "{what}: iteration count");
    assert_eq!(a.cg.converged, b.cg.converged, "{what}: converged flag");
    assert_eq!(a.cg.final_relres.to_bits(), b.cg.final_relres.to_bits(), "{what}: final relres");
    assert_eq!(a.cg.residual_history.len(), b.cg.residual_history.len(), "{what}: history len");
    for (i, (ra, rb)) in a.cg.residual_history.iter().zip(&b.cg.residual_history).enumerate() {
        assert_eq!(ra.to_bits(), rb.to_bits(), "{what}: history[{i}]");
    }
    assert_eq!(a.x.len(), b.x.len());
    for (i, (xa, xb)) in a.x.iter().zip(&b.x).enumerate() {
        assert_eq!(xa.to_bits(), xb.to_bits(), "{what}: x[{i}]");
    }
}

/// Headline parity: profile=on reproduces profile=off bit for bit, in the
/// same single dispatch with the same barrier count, everywhere.
#[test]
fn profiled_solve_is_bitwise_identical_with_zero_new_barriers() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    for ordering in ORDERINGS {
        for spmv in [SpmvKind::Crs, SpmvKind::Sell] {
            let cfg = cfg_for(ordering, spmv, d.shift);
            let plan = SolverPlan::build(&d.matrix, &cfg).expect("plan");
            for nt in [1usize, 4] {
                let what = format!("{ordering:?}/{spmv:?} nt={nt}");
                let plain = run(&plan, &d.b, nt, false);
                assert!(plain.cg.converged, "{what}: baseline must converge");
                assert!(plain.profile.is_none(), "{what}: off must record nothing");
                let profiled = run(&plan, &d.b, nt, true);
                assert_bitwise_equal(&profiled, &plain, &what);
                assert_eq!(profiled.dispatches, 1, "{what}: still one dispatch");
                assert_eq!(
                    profiled.pool_syncs, plain.pool_syncs,
                    "{what}: profiling must add zero pool barriers"
                );
                let p = profiled.profile.as_ref().expect("profile recorded");
                assert_eq!(p.threads(), nt, "{what}: one lane per worker");
            }
        }
    }
}

/// The drained profile of a real solve holds water: totals present for
/// the busy phases, shares normalized, coverage substantial, no dropped
/// spans at the plan's default capacity, imbalance ≥ 1 by construction.
#[test]
fn drained_profile_is_sane() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let cfg = cfg_for(OrderingKind::Hbmc, SpmvKind::Sell, d.shift);
    let plan = SolverPlan::build(&d.matrix, &cfg).expect("plan");
    for nt in [1usize, 2] {
        let out = run(&plan, &d.b, nt, true);
        let p = out.profile.expect("profile recorded");
        let totals = p.phase_totals();
        for (name, t) in PHASE_NAMES.iter().take(4).zip(&totals) {
            assert!(*t > 0.0, "nt={nt}: phase {name} recorded no busy time");
        }
        let shares = p.phase_shares();
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-9, "nt={nt}: {shares:?}");
        assert!(
            p.coverage() > 0.5,
            "nt={nt}: recorded spans cover only {:.1}% of thread-time",
            100.0 * p.coverage()
        );
        assert_eq!(p.dropped(), 0, "nt={nt}: default capacity must hold a Tiny solve");
        assert!(p.barrier_wait_imbalance() >= 1.0, "nt={nt}: max/mean is at least 1");
        for lane in &p.lanes {
            assert!(!lane.spans.is_empty(), "nt={nt}: every lane recorded spans");
        }
    }
}

fn assert_trace_structurally_valid(trace: &str, nthreads: usize) {
    let j = Json::parse(trace).expect("chrome trace must be valid JSON");
    let events = j.get("traceEvents").and_then(Json::as_arr).expect("traceEvents array");
    assert!(!events.is_empty(), "a real solve must produce events");
    let mut last_end = vec![0.0f64; nthreads];
    for ev in events {
        let name = ev.get("name").and_then(Json::as_str).expect("name");
        assert!(PHASE_NAMES.contains(&name), "unknown event name {name}");
        assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
        let tid = ev.get("tid").and_then(Json::as_usize).expect("tid");
        assert!(tid < nthreads, "tid {tid} out of range");
        let ts = ev.get("ts").and_then(Json::as_f64).expect("ts");
        let dur = ev.get("dur").and_then(Json::as_f64).expect("dur");
        assert!(dur > 0.0, "zero-length events are elided");
        // Per-thread timeline is monotone and non-overlapping (1 ns slack
        // for the µs rounding in the exporter).
        assert!(ts + 1e-3 >= last_end[tid], "overlap on tid {tid}: {ts} < {}", last_end[tid]);
        last_end[tid] = ts + dur;
    }
}

/// The chrome-trace export of an actual multi-threaded solve — not a
/// hand-built recorder — is structurally valid.
#[test]
fn chrome_trace_of_a_real_solve_is_structurally_valid() {
    let d = suite::dataset("thermal2", Scale::Tiny);
    let cfg = cfg_for(OrderingKind::Hbmc, SpmvKind::Sell, d.shift);
    let plan = SolverPlan::build(&d.matrix, &cfg).expect("plan");
    let nt = 2;
    let out = run(&plan, &d.b, nt, true);
    let p: &PhaseProfile = out.profile.as_ref().expect("profile recorded");
    assert_trace_structurally_valid(&chrome_trace_json(p), nt);
}

/// The profile rides the full API stack: `SolveOptions::profiled()` on a
/// session solve lands on `SolveReport::profile`, and a plain solve does
/// not pay for (or carry) one.
#[test]
fn session_surfaces_the_profile_on_request_only() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let cfg = cfg_for(OrderingKind::Hbmc, SpmvKind::Sell, d.shift);
    let service = SolverService::with_config(cfg.clone()).expect("service");
    let handle = service.register_matrix(d.matrix.clone());
    let session = service.session(handle, &cfg).expect("session");

    let plain = session.solve(&d.b).expect("solve");
    assert!(plain.report.profile.is_none(), "profiling is strictly opt-in");

    let out = session.solve_with(&d.b, &SolveOptions::profiled()).expect("profiled solve");
    let p = out.report.profile.as_ref().expect("report carries the profile");
    assert!(p.coverage() > 0.0);
    assert_trace_structurally_valid(&chrome_trace_json(p), p.threads());
}

/// The lifecycle trace ring exports well-formed JSON: an array of
/// `{"job","stage","t_us","detail"}` objects with the stages in causal
/// order per job — validated structurally, not by substring grep.
#[test]
fn lifecycle_trace_json_is_structurally_valid() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let mut cfg = cfg_for(OrderingKind::Hbmc, SpmvKind::Sell, d.shift);
    cfg.queue.trace_sample = 1;
    let service = SolverService::with_config(cfg).expect("service");
    let handle = service.register_matrix(d.matrix.clone());
    assert_eq!(service.trace_json(), "[]");
    service.solve(handle, &d.b).expect("solve");

    let j = Json::parse(&service.trace_json()).expect("trace ring must be valid JSON");
    let events = j.as_arr().expect("top-level JSON array");
    let mut last_t = 0u64;
    let mut stages = Vec::new();
    for ev in events {
        let stage = ev.get("stage").and_then(Json::as_str).expect("stage");
        assert!(ev.get("job").and_then(Json::as_u64).is_some(), "job id");
        let t = ev.get("t_us").and_then(Json::as_u64).expect("t_us");
        assert!(t >= last_t, "events are oldest-first");
        last_t = t;
        stages.push(stage.to_string());
    }
    for stage in ["submitted", "enqueued", "batch_opened", "dispatched", "completed"] {
        assert!(stages.iter().any(|s| s == stage), "missing stage {stage}: {stages:?}");
    }
}
