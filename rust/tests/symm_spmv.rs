//! Acceptance suite for the symmetric SpMV engine (ISSUE 6).
//!
//! Contract under test:
//!
//! * `SpmvKind::SymmCsr` computes the same `A·x` as full-CSR within
//!   1e-13 relative error, in both the conflict-free colored mode and the
//!   buffered fallback, at every thread count;
//! * the fused CG loop under SymmCsr converges in exactly the CRS
//!   iteration count, and re-runs are bitwise identical — across runs,
//!   across thread counts {1, 2, 4}, and between the fused and legacy
//!   execution paths;
//! * a converged fused SymmCsr solve is exactly **one** pool dispatch and
//!   its barrier count matches the shaped sync model
//!   (`syncs_per_fused_iteration_shaped`);
//! * the RACE-style schedule is a conflict-free row partition;
//! * the tuner grid races SymmCsr and invalid combinations (σ on a
//!   symmetric plan, an asymmetric matrix) fail typed `InvalidConfig`.

use std::collections::HashSet;

use hbmc::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::metrics::syncs_per_fused_iteration_shaped;
use hbmc::coordinator::pool::Pool;
use hbmc::error::HbmcError;
use hbmc::gen::suite;
use hbmc::ordering::race::RaceSchedule;
use hbmc::solver::plan::{ExecOptions, SolveOutcome, SolverPlan};
use hbmc::solver::spmv::{spmv_symm, SymmSpmv};
use hbmc::sparse::coo::Coo;
use hbmc::sparse::csr::Csr;
use hbmc::tune::{ConfigSpace, HardwareSignature};
use hbmc::util::rng::Rng;

const ORDERINGS: [OrderingKind; 4] = [
    OrderingKind::Natural,
    OrderingKind::Mc,
    OrderingKind::Bmc,
    OrderingKind::Hbmc,
];

/// Random exactly-symmetric positive-ish matrix. Off-diagonal pairs are
/// deduplicated so mirror entries stay bitwise equal through COO
/// duplicate summation.
fn random_sym(n: usize, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut coo = Coo::new(n);
    let mut used: HashSet<(usize, usize)> = HashSet::new();
    for i in 0..n {
        coo.push(i, i, 8.0 + rng.f64());
    }
    for _ in 0..3 * n {
        let i = rng.below(n);
        let j = rng.below(n);
        let (lo, hi) = (i.min(j), i.max(j));
        if lo != hi && used.insert((lo, hi)) {
            coo.push_sym(hi, lo, -1.0 + 0.25 * rng.f64());
        }
    }
    coo.to_csr()
}

fn cfg_for(ordering: OrderingKind, spmv: SpmvKind, shift: f64) -> SolverConfig {
    SolverConfig {
        ordering,
        bs: 8,
        w: 4,
        spmv,
        shift,
        rtol: 1e-6,
        threads: 1,
        ..Default::default()
    }
}

fn run(plan: &SolverPlan, b: &[f64], nt: usize, legacy: bool) -> SolveOutcome {
    let pool = Pool::new(nt);
    plan.execute(
        &pool,
        b,
        &ExecOptions { record_history: true, legacy_loop: legacy, ..Default::default() },
    )
    .expect("solve")
}

fn assert_bitwise_equal(a: &SolveOutcome, b: &SolveOutcome, what: &str) {
    assert_eq!(a.cg.iterations, b.cg.iterations, "{what}: iteration count");
    assert_eq!(a.cg.converged, b.cg.converged, "{what}: converged flag");
    assert_eq!(a.cg.final_relres.to_bits(), b.cg.final_relres.to_bits(), "{what}: final relres");
    assert_eq!(a.cg.residual_history.len(), b.cg.residual_history.len(), "{what}: history length");
    for (i, (ra, rb)) in a.cg.residual_history.iter().zip(&b.cg.residual_history).enumerate() {
        assert_eq!(ra.to_bits(), rb.to_bits(), "{what}: history[{i}]");
    }
    assert_eq!(a.x.len(), b.x.len());
    for (i, (xa, xb)) in a.x.iter().zip(&b.x).enumerate() {
        assert_eq!(xa.to_bits(), xb.to_bits(), "{what}: x[{i}]");
    }
}

/// SymmCsr ≡ full CSR within 1e-13 on random suites, in both engine
/// modes, at every pool width.
#[test]
fn symm_engine_matches_full_csr_on_random_suites() {
    for (n, seed) in [(60usize, 1u64), (257, 7), (1024, 42)] {
        let a = random_sym(n, seed);
        let x: Vec<f64> = (0..n).map(|i| 0.5 + ((i * 37) % 19) as f64 * 0.125).collect();
        let mut want = vec![0.0f64; n];
        a.mul_vec(&x, &mut want);
        // max_colors = 64 → colored; max_colors = 0 → buffered fallback.
        for max_colors in [64usize, 0] {
            let s =
                SymmSpmv::build_with_max_colors(&a, max_colors).expect("symmetric matrix");
            for nt in [1usize, 2, 4] {
                let pool = Pool::new(nt);
                let mut got = vec![0.0f64; n];
                spmv_symm(&s, &x, &mut got, &pool);
                for i in 0..n {
                    let tol = 1e-13 * want[i].abs().max(1.0);
                    assert!(
                        (got[i] - want[i]).abs() <= tol,
                        "n={n} seed={seed} max_colors={max_colors} nt={nt} row {i}: \
                         {} vs {}",
                        got[i],
                        want[i]
                    );
                }
            }
        }
    }
}

/// The coloring schedule covers every row exactly once and no two rows of
/// one color share a write target (conflict-freedom).
#[test]
fn race_schedule_is_conflict_free_on_suite_matrices() {
    for name in ["g3_circuit", "thermal2"] {
        let d = suite::dataset(name, Scale::Tiny);
        let sched = RaceSchedule::build(&d.matrix);
        let mut seen = vec![false; d.n()];
        for c in 0..sched.num_colors() {
            for &r in sched.color_rows(c) {
                assert!(!seen[r as usize], "{name}: row {r} scheduled twice");
                seen[r as usize] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "{name}: schedule must cover every row");
        assert!(
            sched.is_conflict_free(d.matrix.row_ptr(), d.matrix.cols()),
            "{name}: rows of one color must not share a scatter target"
        );
    }
}

/// Fused CG under SymmCsr: converges in exactly the CRS iteration count
/// (the engine computes the same operator, only the summation order
/// differs) and the solution hits the same target.
#[test]
fn fused_symm_cg_matches_crs_iteration_counts() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    for ordering in ORDERINGS {
        let cfg_crs = cfg_for(ordering, SpmvKind::Crs, d.shift);
        let cfg_symm = cfg_for(ordering, SpmvKind::SymmCsr, d.shift);
        let crs_plan = SolverPlan::build(&d.matrix, &cfg_crs).expect("plan");
        let symm_plan = SolverPlan::build(&d.matrix, &cfg_symm).expect("plan");
        assert!(symm_plan.symm_a.is_some(), "SymmCsr plan must carry the symmetric engine");
        let crs = run(&crs_plan, &d.b, 1, false);
        let symm = run(&symm_plan, &d.b, 1, false);
        assert!(crs.cg.converged && symm.cg.converged, "{ordering:?}: both must converge");
        assert_eq!(
            symm.cg.iterations, crs.cg.iterations,
            "{ordering:?}: iteration counts must match exactly"
        );
        // rhs is A·1, so both solutions approximate the ones vector.
        for x in [&crs.x, &symm.x] {
            let err = x.iter().map(|v| (v - 1.0).abs()).fold(0.0, f64::max);
            assert!(err < 1e-3, "{ordering:?}: solution error {err}");
        }
    }
}

/// Bitwise determinism of the fused SymmCsr path: across repeated runs,
/// across thread counts, and against the legacy per-kernel loop (which
/// drives the same worker).
#[test]
fn fused_symm_is_bitwise_deterministic_across_runs_and_thread_counts() {
    let d = suite::dataset("thermal2", Scale::Tiny);
    let cfg = cfg_for(OrderingKind::Hbmc, SpmvKind::SymmCsr, d.shift);
    let plan = SolverPlan::build(&d.matrix, &cfg).expect("plan");
    let reference = run(&plan, &d.b, 1, false);
    assert!(reference.cg.converged);
    for nt in [1usize, 2, 4] {
        for rep in 0..2 {
            let again = run(&plan, &d.b, nt, false);
            assert_bitwise_equal(&again, &reference, &format!("fused nt={nt} rep={rep}"));
        }
        let legacy = run(&plan, &d.b, nt, true);
        assert_bitwise_equal(&legacy, &reference, &format!("legacy nt={nt}"));
    }
}

/// A converged fused SymmCsr solve is exactly one dispatch, and its
/// barrier count matches the shaped analytic model: init pays the
/// engine's internal barriers once, every steady iteration pays
/// `syncs_per_fused_iteration_shaped`, and the converged final iteration
/// stops after its SpMV + update.
#[test]
fn fused_symm_single_dispatch_with_shaped_sync_accounting() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    for ordering in [OrderingKind::Mc, OrderingKind::Hbmc] {
        let cfg = cfg_for(ordering, SpmvKind::SymmCsr, d.shift);
        let plan = SolverPlan::build(&d.matrix, &cfg).expect("plan");
        let shape = plan.symm_a.as_ref().expect("symmetric engine").sync_shape();
        for nt in [1usize, 4] {
            let fused = run(&plan, &d.b, nt, false);
            assert!(fused.cg.converged);
            assert_eq!(fused.dispatches, 1, "{ordering:?} nt={nt}: one dispatch");
            let nc = plan.trisolver.num_colors();
            let k = fused.cg.iterations;
            assert!(k >= 1);
            let init = 2 * (nc - 1) + 7 + shape.internal_syncs();
            let last = 2 + shape.pq_extra_syncs() + shape.internal_syncs();
            let expected = init + (k - 1) * syncs_per_fused_iteration_shaped(nc, shape) + last;
            assert_eq!(
                fused.pool_syncs as usize, expected,
                "{ordering:?} nt={nt}: shaped sync accounting drifted"
            );
        }
    }
}

/// Invalid SymmCsr combinations fail typed, and the tuner grid races the
/// symmetric engine with the incumbent still leading the candidate list.
#[test]
fn symm_invalid_configs_are_typed_and_tuner_grid_races_symm() {
    // σ is a SELL sorting window; on a symmetric plan it must be rejected
    // at validation time, not deep in a kernel.
    let err = SolverConfig::builder()
        .spmv(SpmvKind::SymmCsr)
        .sell_sigma(Some(32))
        .build()
        .expect_err("sigma on symmcsr must fail");
    assert!(matches!(err, HbmcError::InvalidConfig(_)), "got {err:?}");

    // An asymmetric matrix cannot feed the symmetric engine.
    let mut coo = Coo::new(3);
    for i in 0..3 {
        coo.push(i, i, 4.0);
    }
    coo.push(2, 0, -1.0); // no mirror entry
    let err = SymmSpmv::build(&coo.to_csr()).expect_err("asymmetric matrix must fail");
    assert!(matches!(err, HbmcError::InvalidConfig(_)), "got {err:?}");

    // Grid: SymmCsr present, everything valid, incumbent first.
    let base = SolverConfig::default();
    let space = ConfigSpace::for_hardware(&HardwareSignature::detect());
    let cands = space.enumerate(&base);
    assert_eq!(cands[0].label(), base.label(), "incumbent must lead");
    assert!(cands.iter().any(|c| c.spmv == SpmvKind::SymmCsr), "grid must race SymmCsr");
    for c in &cands {
        c.validate().expect("every candidate must validate");
    }
}
