//! Concurrency contract of the `SolverService` façade (the tentpole
//! guarantee of the typed-API redesign):
//!
//! * one shared service hammered from ≥ 4 threads with mixed matrices and
//!   configs performs **exactly one plan build per distinct `PlanKey`** —
//!   no duplicate ordering/factorization, no poisoned locks,
//! * every concurrent result is **bitwise identical** to the
//!   single-threaded one-shot path.
//!
//! Since the job-queue redesign the blocking `solve`/`solve_with` calls
//! ride the dispatcher and may coalesce into shared batches; the number of
//! dispatched batches is timing-dependent, but the invariants asserted
//! here are not: each batch does exactly one plan checkout, so
//! `cache.hits == batches − builds`, and builds stay exactly one per key.
//!
//! Tests in this binary share the process-wide plan-build counter, so they
//! serialize on a static mutex.

use std::sync::{Arc, Barrier, Mutex, MutexGuard};
use std::thread;

use hbmc::api::{SolveRequest, SolverService};
use hbmc::config::{OrderingKind, Scale, SolverConfig};
use hbmc::coordinator::driver::{solve_opts, SolveOptions};
use hbmc::gen::suite;
use hbmc::solver::plan::plans_built;

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

fn tiny_cfg(ordering: OrderingKind) -> SolverConfig {
    SolverConfig { ordering, bs: 8, w: 4, threads: 1, rtol: 1e-7, ..Default::default() }
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Six threads race on one (matrix, config) key: the build gate must
/// coalesce them into a single `SolverPlan::build`, and all six solutions
/// must be bit-identical to the one-shot driver path.
#[test]
fn same_key_concurrent_requests_build_exactly_once() {
    let _guard = serial();
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let cfg = tiny_cfg(OrderingKind::Hbmc);

    // Single-threaded reference first (it consumes its own plan build).
    let reference = solve_opts(&d.matrix, &d.b, &cfg, &SolveOptions::with_solution()).unwrap();
    let ref_bits = bits(reference.solution.as_ref().unwrap());

    let service = Arc::new(SolverService::with_config(cfg).unwrap());
    let handle = service.register_matrix(d.matrix.clone());
    let builds_before = plans_built();

    const THREADS: usize = 6;
    let barrier = Arc::new(Barrier::new(THREADS));
    let workers: Vec<_> = (0..THREADS)
        .map(|_| {
            let service = Arc::clone(&service);
            let barrier = Arc::clone(&barrier);
            let b = d.b.clone();
            thread::spawn(move || {
                barrier.wait();
                service.solve(handle, &b).unwrap()
            })
        })
        .collect();
    let outputs: Vec<_> = workers.into_iter().map(|t| t.join().unwrap()).collect();

    assert_eq!(
        plans_built(),
        builds_before + 1,
        "concurrent same-key requests must coalesce into one plan build"
    );
    let stats = service.stats();
    assert_eq!(stats.builds, 1);
    assert_eq!(stats.cache.misses, 1);
    // One plan checkout per dispatched batch: all but the building batch hit.
    assert_eq!(stats.cache.hits, stats.batches - 1, "every non-building batch must hit");
    assert!(stats.batches <= THREADS as u64);
    assert_eq!(stats.solves, THREADS as u64);
    assert_eq!(stats.batched_rhs, THREADS as u64);
    for (i, out) in outputs.iter().enumerate() {
        assert!(out.report.converged, "thread {i} did not converge");
        assert_eq!(
            bits(&out.x),
            ref_bits,
            "thread {i}: concurrent result deviates from single-threaded one-shot"
        );
    }
}

/// Eight threads × 4 distinct `PlanKey`s (2 matrices × 2 orderings) × 2
/// repetitions, in thread-dependent order: exactly 4 builds total, every
/// result bit-identical to its single-threaded reference, and the service
/// (its locks in particular) stays healthy afterwards.
#[test]
fn mixed_matrices_and_configs_build_once_per_key() {
    let _guard = serial();
    let datasets =
        [suite::dataset("g3_circuit", Scale::Tiny), suite::dataset("thermal2", Scale::Tiny)];
    let configs = [tiny_cfg(OrderingKind::Hbmc), tiny_cfg(OrderingKind::Bmc)];

    // Single-threaded references for all 4 keys, before counting builds.
    let mut ref_bits = Vec::new();
    for d in &datasets {
        for cfg in &configs {
            let rep = solve_opts(&d.matrix, &d.b, cfg, &SolveOptions::with_solution()).unwrap();
            ref_bits.push(bits(rep.solution.as_ref().unwrap()));
        }
    }

    let service = Arc::new(SolverService::with_capacity(configs[0].clone(), 8).unwrap());
    let handles: Vec<_> =
        datasets.iter().map(|d| service.register_matrix(d.matrix.clone())).collect();
    let rhss: Vec<Arc<Vec<f64>>> = datasets.iter().map(|d| Arc::new(d.b.clone())).collect();
    let builds_before = plans_built();

    const THREADS: usize = 8;
    const REPS: usize = 2;
    let barrier = Arc::new(Barrier::new(THREADS));
    let workers: Vec<_> = (0..THREADS)
        .map(|t| {
            let service = Arc::clone(&service);
            let barrier = Arc::clone(&barrier);
            let handles = handles.clone();
            let rhss = rhss.clone();
            let configs = configs.clone();
            thread::spawn(move || {
                barrier.wait();
                let mut got = Vec::new();
                for rep in 0..REPS {
                    for k in 0..4usize {
                        // Vary the visit order per thread so different keys
                        // are in flight simultaneously.
                        let k = (k + t + rep) % 4;
                        let (di, ci) = (k / 2, k % 2);
                        let req = SolveRequest::new().with_config(configs[ci].clone());
                        let out = service.solve_with(handles[di], &rhss[di], &req).unwrap();
                        got.push((k, bits(&out.x)));
                    }
                }
                got
            })
        })
        .collect();
    let results: Vec<_> = workers.into_iter().map(|t| t.join().unwrap()).collect();

    assert_eq!(
        plans_built(),
        builds_before + 4,
        "exactly one build per distinct (matrix, config) key"
    );
    let stats = service.stats();
    assert_eq!(stats.builds, 4);
    let total = (THREADS * REPS * 4) as u64;
    assert_eq!(stats.solves, total);
    assert_eq!(stats.batched_rhs, total);
    // One plan checkout per dispatched batch: exactly the 4 building
    // batches miss, every other batch hits.
    assert_eq!(stats.cache.misses, 4);
    assert_eq!(stats.cache.hits, stats.batches - 4, "all non-building batches must hit");
    assert_eq!(stats.cache.len, 4);
    assert_eq!(stats.cache.evictions, 0);

    for (t, got) in results.iter().enumerate() {
        for (k, xbits) in got {
            assert_eq!(
                xbits, &ref_bits[*k],
                "thread {t} key {k}: concurrent result deviates from reference"
            );
        }
    }

    // No poisoned locks: the service keeps serving on the same plans.
    let after = service.solve(handles[0], &rhss[0]).unwrap();
    assert!(after.report.converged);
    assert_eq!(plans_built(), builds_before + 4, "post-stress solve must reuse cached plans");
}
