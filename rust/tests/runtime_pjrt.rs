//! PJRT runtime integration: load the AOT HLO artifacts, execute them on
//! the XLA CPU client and compare against the python goldens — the
//! automated version of `examples/hybrid_pjrt.rs`.
//!
//! Skipped when artifacts are absent (`make artifacts`), and compiled out
//! entirely without the `pjrt` cargo feature (the default offline build
//! stubs the executor).

#![cfg(feature = "pjrt")]

use hbmc::runtime::artifacts::ArtifactSet;
use hbmc::runtime::hybrid::{HybridPcgStep, HybridPrecond, HybridSpmv};
use hbmc::runtime::pjrt::PjrtRuntime;
use hbmc::solver::blas1::dot;

fn setup() -> Option<(ArtifactSet, PjrtRuntime)> {
    let arts = match ArtifactSet::locate() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("SKIP pjrt tests: {e:#}");
            return None;
        }
    };
    let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    Some((arts, rt))
}

#[test]
fn precond_executable_matches_golden() {
    let Some((arts, rt)) = setup() else { return };
    let pre = HybridPrecond::load(&rt, &arts).unwrap();
    let golden = arts.golden().unwrap();
    let r = golden.f64_vec("precond_r").unwrap();
    let z_expect = golden.f64_vec("precond_z").unwrap();
    let z = pre.apply(&r).unwrap();
    let dev = hbmc::util::max_abs_diff(&z, &z_expect);
    assert!(dev < 1e-11, "pjrt precond deviates: {dev}");
}

#[test]
fn spmv_executable_matches_golden() {
    let Some((arts, rt)) = setup() else { return };
    let spmv = HybridSpmv::load(&rt, &arts).unwrap();
    let golden = arts.golden().unwrap();
    let x = golden.f64_vec("spmv_x").unwrap();
    let y_expect = golden.f64_vec("spmv_y").unwrap();
    let y = spmv.apply(&x).unwrap();
    let dev = hbmc::util::max_abs_diff(&y, &y_expect);
    assert!(dev < 1e-11, "pjrt spmv deviates: {dev}");
}

#[test]
fn pcg_step_reproduces_python_rr_history() {
    let Some((arts, rt)) = setup() else { return };
    let step = HybridPcgStep::load(&rt, &arts).unwrap();
    let spmv = HybridSpmv::load(&rt, &arts).unwrap();
    let pre = HybridPrecond::load(&rt, &arts).unwrap();
    let golden = arts.golden().unwrap();
    let n = golden.usize("n_aug").unwrap();
    let rr_expect = golden.f64_vec("pcg_rr_history").unwrap();

    // Same initial state as aot.py: b = A·1, x0 = 0.
    let b = spmv.apply(&vec![1.0; n]).unwrap();
    let mut x = vec![0.0; n];
    let mut r = b.clone();
    let z = pre.apply(&r).unwrap();
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    for (i, expect) in rr_expect.iter().enumerate() {
        let (x2, r2, _z2, p2, rz2, rr) = step.step(&x, &r, &p, rz).unwrap();
        x = x2;
        r = r2;
        p = p2;
        rz = rz2;
        let rel = (rr - expect).abs() / expect.abs().max(1e-300);
        assert!(rel < 1e-9, "iter {i}: rr {rr} vs golden {expect} (rel {rel:.2e})");
    }
}

#[test]
fn executables_reject_wrong_dimensions() {
    let Some((arts, rt)) = setup() else { return };
    let pre = HybridPrecond::load(&rt, &arts).unwrap();
    assert!(pre.apply(&[1.0, 2.0]).is_err());
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let Some((_, rt)) = setup() else { return };
    let bogus = ArtifactSet::at(std::path::Path::new("/nonexistent"));
    assert!(rt
        .load_hlo_text(&bogus.hlo_path("precond_hbmc"), 1)
        .is_err());
}
