//! Plan/session integration — the amortization contract of the two-phase
//! refactor:
//!
//! * a reused `SolveSession` runs 8 consecutive solves on one
//!   `Scale::Small` matrix with **exactly one** ordering+factorization
//!   setup (asserted via the global plan-build counter and the plan-cache
//!   hit/miss counters),
//! * per-solve results are **bit-exact** against one-shot `driver::solve`
//!   for all of natural / MC / BMC / HBMC,
//! * `solve_many` over k right-hand sides is bitwise-identical to k
//!   independent one-shot solves,
//! * repeated (matrix, config) requests hit the `PlanCache` (no
//!   re-factorization).
//!
//! Tests in this binary share the process-wide plan-build counter, so they
//! serialize on a static mutex.

use std::sync::{Arc, Mutex, MutexGuard};

use hbmc::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::driver::{solve_opts, SolveOptions};
use hbmc::coordinator::session::{PlanCache, SolveSession};
use hbmc::gen::suite;
use hbmc::solver::plan::plans_built;

static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

const ORDERINGS: [OrderingKind; 4] = [
    OrderingKind::Natural,
    OrderingKind::Mc,
    OrderingKind::Bmc,
    OrderingKind::Hbmc,
];

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The headline acceptance test: 8 solves, one setup, bit-exact vs the
/// one-shot driver, for every ordering, at `Scale::Small`.
#[test]
fn eight_solves_amortize_one_setup_and_match_one_shot_bitwise() {
    let _guard = serial();
    // parabolic_fem is the cheapest Small-scale system to converge
    // (strongly diagonally dominant), keeping 4 orderings × 9 solves sane
    // in debug builds. The contract is scale-independent.
    let d = suite::dataset("parabolic_fem", Scale::Small);
    for ordering in ORDERINGS {
        let cfg = SolverConfig {
            ordering,
            bs: 16,
            w: 4,
            spmv: SpmvKind::Crs,
            threads: 1,
            rtol: 1e-5,
            shift: d.shift,
            ..Default::default()
        };

        let mut cache = PlanCache::new(2);
        let session = cache.session(&d.matrix, &cfg).unwrap();
        assert_eq!((cache.misses(), cache.hits()), (1, 0));

        let builds_before = plans_built();
        let mut solutions: Vec<Vec<f64>> = Vec::new();
        for _ in 0..8 {
            // Re-request the plan per solve, as a serving tier would —
            // every request after the first must be a cache hit.
            let (plan, _) = cache.get_or_build(&d.matrix, &cfg).unwrap();
            assert!(Arc::ptr_eq(&plan, session.plan()), "{ordering:?}: plan changed");
            let out = session.solve(&d.b).unwrap();
            assert!(out.report.converged, "{ordering:?} did not converge");
            solutions.push(out.x);
        }
        assert_eq!(
            plans_built(),
            builds_before,
            "{ordering:?}: a plan was rebuilt during the 8 reused solves"
        );
        assert_eq!(cache.misses(), 1, "{ordering:?}: exactly one setup");
        assert_eq!(cache.hits(), 8, "{ordering:?}: all repeat requests must hit");
        assert_eq!(session.solves_completed(), 8);

        // All 8 session solves are bitwise identical to each other…
        for (k, x) in solutions.iter().enumerate().skip(1) {
            assert_eq!(bits(x), bits(&solutions[0]), "{ordering:?}: solve {k} deviates");
        }
        // …and to a fresh one-shot driver::solve (same deterministic path).
        let one = solve_opts(&d.matrix, &d.b, &cfg, &SolveOptions::with_solution()).unwrap();
        assert_eq!(
            bits(one.solution.as_ref().unwrap()),
            bits(&solutions[0]),
            "{ordering:?}: session result deviates from one-shot driver::solve"
        );
    }
}

/// `solve_many` over k distinct right-hand sides ≡ k independent one-shot
/// solves, for every ordering × SpMV storage.
#[test]
fn solve_many_is_bitwise_identical_to_one_shot_for_every_ordering() {
    let _guard = serial();
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let rhss: Vec<Vec<f64>> = (0..3)
        .map(|k| d.b.iter().map(|v| v * (1.0 + 0.5 * k as f64)).collect())
        .collect();
    for ordering in ORDERINGS {
        for spmv in [SpmvKind::Crs, SpmvKind::Sell] {
            let cfg = SolverConfig {
                ordering,
                bs: 8,
                w: 4,
                spmv,
                rtol: 1e-7,
                ..Default::default()
            };
            let session = SolveSession::from_matrix(&d.matrix, &cfg).unwrap();
            let batch = session.solve_many(&rhss).unwrap();
            assert_eq!(batch.len(), rhss.len());
            for (i, (rhs, out)) in rhss.iter().zip(&batch).enumerate() {
                assert!(out.report.converged, "{ordering:?}/{spmv:?} rhs {i}");
                assert_eq!(out.report.solve_index, i);
                let one =
                    solve_opts(&d.matrix, rhs, &cfg, &SolveOptions::with_solution()).unwrap();
                assert_eq!(one.iterations, out.report.iterations, "{ordering:?}/{spmv:?}");
                assert_eq!(
                    bits(one.solution.as_ref().unwrap()),
                    bits(&out.x),
                    "{ordering:?}/{spmv:?} rhs {i}: batched ≠ one-shot"
                );
            }
        }
    }
}

/// Cache hits skip the whole setup phase (no IC(0) re-factorization).
#[test]
fn plan_cache_hits_do_not_refactor() {
    let _guard = serial();
    let d = suite::dataset("thermal2", Scale::Tiny);
    let cfg = SolverConfig { ordering: OrderingKind::Hbmc, bs: 8, w: 4, ..Default::default() };
    let mut cache = PlanCache::new(4);
    let before = plans_built();
    let (p1, hit1) = cache.get_or_build(&d.matrix, &cfg).unwrap();
    assert!(!hit1);
    assert_eq!(plans_built(), before + 1);
    for _ in 0..5 {
        let (p, hit) = cache.get_or_build(&d.matrix, &cfg).unwrap();
        assert!(hit);
        assert!(Arc::ptr_eq(&p, &p1));
    }
    assert_eq!(plans_built(), before + 1, "cache hits must not re-run setup");
    assert_eq!((cache.hits(), cache.misses()), (5, 1));
}

/// The report split keeps per-plan (setup) metrics constant across solves
/// while per-solve metrics vary, and neither clones the solution by
/// default.
#[test]
fn report_split_exposes_amortization() {
    let _guard = serial();
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let cfg = SolverConfig {
        ordering: OrderingKind::Hbmc,
        bs: 8,
        w: 4,
        spmv: SpmvKind::Sell,
        rtol: 1e-7,
        ..Default::default()
    };
    let session = SolveSession::from_matrix(&d.matrix, &cfg).unwrap();
    let reports: Vec<_> = (0..3).map(|_| session.solve(&d.b).unwrap().report).collect();
    for (i, rep) in reports.iter().enumerate() {
        assert_eq!(rep.solve_index, i);
        assert!(rep.solution.is_none(), "solution must be opt-in");
        assert!(rep.residual_history.is_empty(), "history must be opt-in");
        // Per-plan metrics are those of the single shared setup.
        assert_eq!(
            rep.plan.setup.ordering_seconds.to_bits(),
            reports[0].plan.setup.ordering_seconds.to_bits()
        );
        assert_eq!(
            rep.plan.setup.factor_seconds.to_bits(),
            reports[0].plan.setup.factor_seconds.to_bits()
        );
        assert_eq!(rep.plan.config_label, reports[0].plan.config_label);
        assert!(rep.solve_seconds > 0.0);
    }
}
