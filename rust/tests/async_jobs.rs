//! Contract of the asynchronous job API (the tentpole of the queue
//! redesign):
//!
//! * **coalescing** — M threads each submitting one rhs for the same
//!   (matrix, config) key produce *fewer than M* dispatched batches, with
//!   mean batch width > 1, and every result is bitwise-identical to the
//!   single-threaded one-shot path;
//! * **deadlines** — a job still queued past its budget fails typed with
//!   `HbmcError::DeadlineExceeded` and never runs;
//! * **cancellation** — a queued job can be cancelled (typed
//!   `HbmcError::Cancelled`, never runs); running/terminal jobs cannot;
//! * **blocking wrappers** — `solve`/`solve_many` ride the same queue and
//!   stay index-aligned and bit-identical.

use std::sync::{Arc, Barrier};
use std::thread;
use std::time::Duration;

use hbmc::api::{HbmcError, JobState, SolveRequest, SolverService};
use hbmc::config::{OrderingKind, Scale, SolverConfig};
use hbmc::coordinator::driver::{solve_opts, SolveOptions};
use hbmc::gen::suite;

fn tiny_cfg(ordering: OrderingKind) -> SolverConfig {
    SolverConfig { ordering, bs: 8, w: 4, threads: 1, rtol: 1e-7, ..Default::default() }
}

fn bits(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// The acceptance test: M concurrent single-RHS submissions for one
/// `PlanKey` coalesce into fewer than M dispatched batches (width > 1),
/// with results bitwise-identical to sequential one-shot solves.
#[test]
fn concurrent_submissions_coalesce_into_wide_batches() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let mut cfg = tiny_cfg(OrderingKind::Hbmc);
    // A generous flush window + room for all jobs in one batch makes the
    // coalescing deterministic: every submit lands well inside the window.
    cfg.queue.max_batch = 16;
    cfg.queue.max_wait = Duration::from_millis(300);

    // Single-threaded one-shot reference, per distinct rhs.
    const M: usize = 8;
    let rhss: Vec<Vec<f64>> = (0..M)
        .map(|k| d.b.iter().map(|v| v * (1.0 + (k % 3) as f64)).collect())
        .collect();
    let mut ref_bits = Vec::new();
    for rhs in &rhss {
        let rep = solve_opts(&d.matrix, rhs, &cfg, &SolveOptions::with_solution()).unwrap();
        ref_bits.push(bits(rep.solution.as_ref().unwrap()));
    }

    let service = Arc::new(SolverService::with_config(cfg).unwrap());
    let handle = service.register_matrix(d.matrix.clone());
    let barrier = Arc::new(Barrier::new(M));
    let workers: Vec<_> = (0..M)
        .map(|k| {
            let service = Arc::clone(&service);
            let barrier = Arc::clone(&barrier);
            let rhs = rhss[k].clone();
            thread::spawn(move || {
                barrier.wait();
                service.submit(handle, &rhs, &SolveRequest::new()).unwrap().wait().unwrap()
            })
        })
        .collect();
    let outputs: Vec<_> = workers.into_iter().map(|t| t.join().unwrap()).collect();

    for (k, out) in outputs.iter().enumerate() {
        assert!(out.report.converged, "job {k} did not converge");
        assert_eq!(
            bits(&out.x),
            ref_bits[k],
            "job {k}: coalesced result deviates from the sequential one-shot"
        );
    }
    let st = service.stats();
    assert_eq!(st.solves, M as u64);
    assert_eq!(st.batched_rhs, M as u64);
    assert!(
        st.batches < M as u64,
        "{M} same-key jobs must coalesce into fewer than {M} batches, got {}",
        st.batches
    );
    assert!(
        st.mean_batch_width() > 1.0,
        "mean batch width must exceed 1, got {:.2}",
        st.mean_batch_width()
    );
    assert!(st.coalesced_rhs >= 2, "at least one batch must have shared a session");
    assert_eq!(st.builds, 1, "one plan build for one key");
    assert_eq!(st.queue_depth, 0, "queue must drain");
}

/// A job whose budget is already spent when the dispatcher reaches it
/// fails with the documented typed error and never runs. (A zero budget
/// never even enqueues — `submit` rejects it synchronously; that contract
/// lives in tests/overload.rs.)
#[test]
fn expired_deadline_is_typed_and_never_runs() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let cfg = tiny_cfg(OrderingKind::Hbmc);
    let service = SolverService::with_config(cfg).unwrap();
    let handle = service.register_matrix(d.matrix.clone());
    // Warm the plan so a *dispatched* job would be fast — the failure below
    // is strictly the deadline, not load.
    service.solve(handle, &d.b).unwrap();
    let solves_before = service.stats().solves;

    // The smallest positive budget passes the synchronous zero-deadline
    // check at submit, but is always spent by the time the dispatcher
    // claims the job — it must be shed, never run.
    let req = SolveRequest::new().deadline(Duration::from_nanos(1));
    let job = service.submit(handle, &d.b, &req).unwrap();
    let err = job.wait().unwrap_err();
    assert!(matches!(err, HbmcError::DeadlineExceeded { .. }), "{err:?}");

    // Observable through poll() as well.
    let job = service.submit(handle, &d.b, &req).unwrap();
    let state = loop {
        let s = job.poll();
        if s.is_terminal() {
            break s;
        }
        thread::yield_now();
    };
    assert_eq!(state, JobState::DeadlineExceeded);
    assert!(matches!(job.wait(), Err(HbmcError::DeadlineExceeded { .. })));
    assert_eq!(
        service.stats().solves,
        solves_before,
        "expired jobs must never reach the solver"
    );
    assert_eq!(service.stats().shed, 2, "both expired jobs count as shed at dispatch");
}

/// Cancel aborts queued jobs (typed error, no solve); terminal jobs
/// cannot be cancelled; and a job busy in another key's batch window
/// stays cancellable the whole time it is queued.
#[test]
fn cancel_aborts_queued_jobs_only() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let mut cfg = tiny_cfg(OrderingKind::Hbmc);
    // Long flush window: job A holds the dispatcher in its batch window
    // while job B (a different BatchKey) sits queued — deterministically
    // cancellable even on a heavily loaded CI machine.
    cfg.queue.max_wait = Duration::from_millis(800);
    cfg.queue.max_batch = 4;
    let service = SolverService::with_config(cfg).unwrap();
    let handle = service.register_matrix(d.matrix.clone());

    let job_a = service.submit(handle, &d.b, &SolveRequest::new()).unwrap();
    let req_b = SolveRequest::new().with_config(tiny_cfg(OrderingKind::Bmc));
    let job_b = service.submit(handle, &d.b, &req_b).unwrap();

    assert!(job_b.cancel(), "job queued behind another key's window must cancel");
    assert!(!job_b.cancel(), "second cancel is a no-op");
    assert_eq!(job_b.poll(), JobState::Cancelled);
    let err = job_b.wait().unwrap_err();
    assert!(matches!(err, HbmcError::Cancelled), "{err:?}");

    let out = job_a.wait().unwrap();
    assert!(out.report.converged);
    let st = service.stats();
    assert_eq!(st.solves, 1, "the cancelled job must never run");
    assert_eq!(st.builds, 1, "the cancelled job must not build its plan");

    // A finished job is not cancellable.
    let job_c = service.submit(handle, &d.b, &SolveRequest::new()).unwrap();
    while !job_c.poll().is_terminal() {
        thread::yield_now();
    }
    assert!(!job_c.cancel(), "terminal jobs must not be cancellable");
    assert!(job_c.wait().is_ok());
}

/// The blocking batch wrapper rides the queue, keeps results index-aligned
/// with the submitted rhss, and matches independent one-shot solves
/// bitwise.
#[test]
fn solve_many_stays_aligned_and_bit_identical() {
    let d = suite::dataset("thermal2", Scale::Tiny);
    let cfg = tiny_cfg(OrderingKind::Bmc);
    let service = SolverService::with_config(cfg.clone()).unwrap();
    let handle = service.register_matrix(d.matrix.clone());
    let b2: Vec<f64> = d.b.iter().map(|v| 2.0 * v).collect();
    let b3: Vec<f64> = d.b.iter().map(|v| -0.5 * v).collect();
    let rhss = [d.b.clone(), b2, b3];
    let outs = service.solve_many(handle, &rhss).unwrap();
    assert_eq!(outs.len(), 3);
    for (rhs, out) in rhss.iter().zip(&outs) {
        let rep = solve_opts(&d.matrix, rhs, &cfg, &SolveOptions::with_solution()).unwrap();
        assert_eq!(
            bits(&out.x),
            bits(rep.solution.as_ref().unwrap()),
            "queued batch result must match the one-shot path bitwise"
        );
        assert_eq!(out.report.iterations, rep.iterations);
    }
    let st = service.stats();
    assert_eq!(st.solves, 3);
    assert_eq!(st.batched_rhs, 3);
    assert_eq!(st.builds, 1);
    assert_eq!(st.queue_depth, 0);
}

/// Dropping the service is a graceful shutdown: already-submitted jobs
/// are flushed and their handles resolve.
#[test]
fn drop_flushes_queued_jobs() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let mut cfg = tiny_cfg(OrderingKind::Hbmc);
    cfg.queue.max_wait = Duration::from_millis(100);
    let service = SolverService::with_config(cfg).unwrap();
    let handle = service.register_matrix(d.matrix.clone());
    let jobs: Vec<_> = (0..3)
        .map(|_| service.submit(handle, &d.b, &SolveRequest::new()).unwrap())
        .collect();
    drop(service);
    for (k, job) in jobs.into_iter().enumerate() {
        let out = job.wait().unwrap_or_else(|e| panic!("job {k} lost in shutdown: {e}"));
        assert!(out.report.converged, "job {k}");
    }
}
