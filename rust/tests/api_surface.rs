//! The typed public API surface: every failure mode of the library comes
//! back as a matchable [`HbmcError`] variant — no stringly-typed errors,
//! no panics on malformed requests.

use hbmc::api::{SolveRequest, SolverService};
use hbmc::config::{OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::session::SolveSession;
use hbmc::error::HbmcError;
use hbmc::gen::suite;
use hbmc::solver::plan::SolverPlan;

fn tiny_cfg(ordering: OrderingKind) -> SolverConfig {
    SolverConfig { ordering, bs: 8, w: 4, rtol: 1e-7, ..Default::default() }
}

/// A wrong-length rhs must come back as `DimensionMismatch` carrying the
/// expected and observed lengths — from `solve`, never a panic.
#[test]
fn session_solve_reports_dimension_mismatch() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let n = d.matrix.n();
    let session = SolveSession::from_matrix(&d.matrix, &tiny_cfg(OrderingKind::Hbmc)).unwrap();

    for bad_len in [0usize, 3, n - 1, n + 1] {
        let bad = vec![1.0; bad_len];
        let err = session.solve(&bad).unwrap_err();
        assert!(
            matches!(err, HbmcError::DimensionMismatch { expected, got }
                if expected == n && got == bad_len),
            "len {bad_len}: {err:?}"
        );
    }
    // A well-formed rhs still works on the same session afterwards.
    assert!(session.solve(&d.b).unwrap().report.converged);
}

/// …and from `solve_many`, where a single malformed rhs in the batch is
/// enough to fail it.
#[test]
fn session_solve_many_reports_dimension_mismatch() {
    let d = suite::dataset("thermal2", Scale::Tiny);
    let n = d.matrix.n();
    let session = SolveSession::from_matrix(&d.matrix, &tiny_cfg(OrderingKind::Bmc)).unwrap();
    let err = session.solve_many(&[d.b.clone(), vec![1.0; 5]]).unwrap_err();
    assert!(
        matches!(err, HbmcError::DimensionMismatch { expected, got }
            if expected == n && got == 5),
        "{err:?}"
    );
}

/// The same contract at the service layer, where the whole batch is
/// validated up front (nothing runs before the reject).
#[test]
fn service_rejects_batch_with_any_bad_rhs_before_solving() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
    let h = svc.register_matrix(d.matrix.clone());
    let err = svc.solve_many(h, &[d.b.clone(), d.b[..d.b.len() - 1].to_vec()]).unwrap_err();
    assert!(matches!(err, HbmcError::DimensionMismatch { .. }), "{err:?}");
    assert_eq!(svc.stats().solves, 0, "no rhs of a rejected batch may run");
}

/// A non-finite right-hand side is rejected at `submit`, typed and
/// synchronous, *naming the first offending index* — before the job ever
/// touches the queue, the plan cache, or a solver (a NaN entering the
/// fused CG loop would otherwise cost a full breakdown-recovery cycle).
#[test]
fn non_finite_rhs_rejected_at_submit_with_index() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
    let h = svc.register_matrix(d.matrix.clone());
    for (idx, bad_val) in [(0usize, f64::NAN), (3, f64::INFINITY), (7, f64::NEG_INFINITY)] {
        let mut rhs = d.b.clone();
        rhs[idx] = bad_val;
        let err = svc.submit(h, &rhs, &SolveRequest::new()).unwrap_err();
        assert!(matches!(err, HbmcError::InvalidConfig(_)), "{err:?}");
        assert!(
            err.to_string().contains(&format!("rhs[{idx}]")),
            "must name the first bad index: {err}"
        );
    }
    // A batch with one bad rhs fails the same way, before anything runs.
    let mut bad = d.b.clone();
    bad[5] = f64::NAN;
    let err = svc.solve_many(h, &[d.b.clone(), bad]).unwrap_err();
    assert!(matches!(err, HbmcError::InvalidConfig(_)), "{err:?}");
    assert!(err.to_string().contains("rhs[5]"), "{err}");
    let st = svc.stats();
    assert_eq!(st.solves, 0, "a rejected rhs must never reach the solver");
    assert_eq!(st.batches, 0, "…nor open a batch");
    // The same handle still serves well-formed work afterwards.
    assert!(svc.solve(h, &d.b).unwrap().report.converged);
}

/// The HBMC structural constraint is validated before any kernel sees the
/// config: `bs` must be a multiple of `w`.
#[test]
fn hbmc_bs_not_multiple_of_w_is_invalid_config() {
    let a = suite::dataset("g3_circuit", Scale::Tiny).matrix;
    let cfg = SolverConfig { ordering: OrderingKind::Hbmc, bs: 12, w: 8, ..Default::default() };
    let err = SolverPlan::build(&a, &cfg).unwrap_err();
    assert!(matches!(err, HbmcError::InvalidConfig(_)), "{err:?}");
    assert!(err.to_string().contains("multiple of w"), "{err}");

    let err = SolverConfig::builder().ordering(OrderingKind::Hbmc).bs(12).w(8).build().unwrap_err();
    assert!(matches!(err, HbmcError::InvalidConfig(_)), "{err:?}");

    // BMC has no level-2 packing; the same shape is legal there.
    assert!(SolverConfig::builder().ordering(OrderingKind::Bmc).bs(12).w(8).build().is_ok());
}

/// The enums round-trip through the standard `FromStr`/`Display` traits.
#[test]
fn config_enums_parse_and_display() {
    let cfg = SolverConfig::builder()
        .ordering("hbmc".parse().unwrap())
        .spmv("sell".parse().unwrap())
        .bs(16)
        .w(4)
        .build()
        .unwrap();
    assert_eq!(cfg.label(), "HBMC(bs=16,w=4,sell)");
    assert_eq!(cfg.ordering, OrderingKind::Hbmc);
    assert_eq!(cfg.spmv, SpmvKind::Sell);
    let err = "rainbow".parse::<Scale>().unwrap_err();
    assert!(matches!(err, HbmcError::Parse(_)), "{err:?}");
}

/// Every enum variant's `Display` parses back to itself, and unknown
/// strings are `HbmcError::Parse` — for all four config enums.
#[test]
fn config_enums_round_trip_exhaustively() {
    use hbmc::config::NodePreset;
    for k in [
        OrderingKind::Natural,
        OrderingKind::Mc,
        OrderingKind::Bmc,
        OrderingKind::Hbmc,
        OrderingKind::Level,
    ] {
        assert_eq!(k.to_string().parse::<OrderingKind>().unwrap(), k);
    }
    for v in [SpmvKind::Crs, SpmvKind::Sell] {
        assert_eq!(v.to_string().parse::<SpmvKind>().unwrap(), v);
    }
    for s in [Scale::Tiny, Scale::Small, Scale::Full] {
        assert_eq!(s.to_string().parse::<Scale>().unwrap(), s);
    }
    for n in NodePreset::all() {
        assert_eq!(n.to_string().parse::<NodePreset>().unwrap(), n);
    }
    assert!(matches!("nope".parse::<OrderingKind>(), Err(HbmcError::Parse(_))));
    assert!(matches!("nope".parse::<SpmvKind>(), Err(HbmcError::Parse(_))));
    assert!(matches!("nope".parse::<Scale>(), Err(HbmcError::Parse(_))));
    assert!(matches!("nope".parse::<NodePreset>(), Err(HbmcError::Parse(_))));
}

/// Unknown dataset names and stale handles are `UnknownMatrix`.
#[test]
fn unknown_matrix_is_typed() {
    let err = suite::try_dataset("not_in_suite", Scale::Tiny).unwrap_err();
    assert!(matches!(err, HbmcError::UnknownMatrix(_)), "{err:?}");
    assert!(err.to_string().contains("not_in_suite"));
}

/// A solve that must converge but hits the cap is `NotConverged` with the
/// observed iteration count and residual.
#[test]
fn capped_solve_with_required_convergence_is_typed() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let svc = SolverService::with_config(tiny_cfg(OrderingKind::Hbmc)).unwrap();
    let h = svc.register_matrix(d.matrix.clone());
    let req = SolveRequest::new().max_iters(3).require_convergence();
    let err = svc.solve_with(h, &d.b, &req).unwrap_err();
    match err {
        HbmcError::NotConverged { iterations, relres } => {
            assert_eq!(iterations, 3);
            assert!(relres > 0.0);
        }
        other => panic!("expected NotConverged, got {other:?}"),
    }
}

/// Missing files surface as `Io` with the path in the message and the
/// `std::io::Error` preserved as `source()`.
#[test]
fn missing_matrix_market_file_is_io() {
    use std::error::Error as _;
    let err =
        hbmc::sparse::matrix_market::read(std::path::Path::new("/nonexistent/a.mtx")).unwrap_err();
    assert!(matches!(err, HbmcError::Io { .. }), "{err:?}");
    assert!(err.to_string().contains("/nonexistent/a.mtx"), "{err}");
    assert!(err.source().is_some());
}
