//! Coordinator-level integration: pool stress, experiment harness
//! invariants (the automated versions of the paper's tables at tiny
//! scale), and report plumbing.

use hbmc::config::{NodePreset, OrderingKind, Scale, SolverConfig, SpmvKind};
use hbmc::coordinator::driver::{solve_opts, SolveOptions};
use hbmc::coordinator::experiments;
use hbmc::coordinator::pool::{Pool, SyncSlice};
use hbmc::gen::suite;
use std::sync::atomic::{AtomicUsize, Ordering};

#[test]
fn pool_stress_many_jobs_many_barriers() {
    let pool = Pool::new(4);
    let counter = AtomicUsize::new(0);
    for _ in 0..200 {
        pool.run(&|_, _| {
            counter.fetch_add(1, Ordering::Relaxed);
            pool.color_barrier();
            counter.fetch_add(1, Ordering::Relaxed);
        });
    }
    assert_eq!(counter.load(Ordering::Relaxed), 200 * 4 * 2);
    assert_eq!(pool.sync_count(), 200);
}

#[test]
fn pool_pipeline_ordering_with_barriers() {
    // Simulated 3-color substitution: each color reads the previous
    // color's writes; repeated many times to shake out races.
    let pool = Pool::new(3);
    let n = 3 * 64;
    for round in 0..50 {
        let mut data = vec![0u64; n];
        let ds = SyncSlice::new(&mut data);
        pool.run(&|tid, nt| {
            for color in 0..3usize {
                let lo = color * 64;
                let range = Pool::chunk(64, tid, nt);
                for i in lo + range.start..lo + range.end {
                    let prev = if color == 0 {
                        1
                    } else {
                        unsafe { ds.get(i - 64) }
                    };
                    unsafe { ds.set(i, prev + 1) };
                }
                if color < 2 {
                    pool.color_barrier();
                }
            }
        });
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v as usize, i / 64 + 2, "round {round} index {i}");
        }
    }
}

#[test]
fn table_5_2_harness_reproduces_equivalence() {
    let (table, raw) = experiments::table_5_2(Scale::Tiny, 2).unwrap();
    let rendered = table.render();
    assert!(rendered.contains("thermal2") && rendered.contains("ieej"));
    for iters in &raw {
        assert!(iters[1].abs_diff(iters[2]) <= 2 + iters[1] / 20, "BMC != HBMC");
    }
}

#[test]
fn fig_5_1_harness_emits_overlapping_curves() {
    let curves = experiments::fig_5_1(&["ieej"], Scale::Tiny, 1).unwrap();
    let (name, bmc, hbmc) = &curves[0];
    assert_eq!(name, "ieej");
    assert!(!bmc.is_empty());
    assert_eq!(bmc.len(), hbmc.len());
    // Monotone-ish decrease overall: final < initial.
    assert!(bmc.last().unwrap() < bmc.first().unwrap());
}

#[test]
fn sell_overhead_statistic_shape() {
    let t = experiments::sell_overhead_stat(Scale::Tiny).unwrap();
    assert_eq!(t.rows.len(), 5);
    let rendered = t.render();
    assert!(rendered.contains("audikw_1"));
}

#[test]
fn solve_report_kernel_breakdown_sums_to_solve_time() {
    let d = suite::dataset("g3_circuit", Scale::Tiny);
    let cfg = SolverConfig {
        ordering: OrderingKind::Hbmc,
        bs: 8,
        w: 4,
        spmv: SpmvKind::Sell,
        rtol: 1e-7,
        ..Default::default()
    };
    let rep = solve_opts(&d.matrix, &d.b, &cfg, &SolveOptions::default()).unwrap();
    let parts: f64 = rep.kernel_seconds.iter().map(|(_, s)| s).sum();
    assert!(parts <= rep.solve_seconds * 1.05, "{parts} vs {}", rep.solve_seconds);
    assert!(parts >= rep.solve_seconds * 0.5, "breakdown lost time: {parts} vs {}", rep.solve_seconds);
}

#[test]
fn node_presets_differ_in_w() {
    let ws: Vec<usize> = NodePreset::all().iter().map(|n| n.w()).collect();
    assert_eq!(ws, vec![8, 4, 8]);
}
